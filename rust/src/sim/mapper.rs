//! GEMM→core mapping strategies (paper §II-B).
//!
//! Photonic GEMM cores admit *temporal*, *spatial* and *mixed
//! spatio-temporal* mappings, with the extra spatial freedom of mapping by
//! wavelength or by waveguide. At the transaction level the choice shows up
//! as the **tile iteration order**, which determines how often the MRR
//! weight banks must be reprogrammed (a DAC write per ring) versus how long
//! input rows stream unchanged:
//!
//! * **Weight-stationary** (the paper's Fig. 1 mapping): a (K-chunk,
//!   C-tile) weight block is loaded once and all T input rows stream
//!   through it. Weight loads: `ceil(K/N)·ceil(C/M)` per GEMM.
//! * **Output-stationary**: for each output tile, iterate K-chunks back to
//!   back so the BPCA accumulates without intermediate digitization —
//!   same weight-load count, but *baselines* avoid one SRAM round-trip per
//!   pass at the cost of re-streaming inputs per C-tile.
//! * **Input-stationary**: an input row block is held (modulators static)
//!   while weight tiles cycle; weight loads scale with T — only sensible
//!   when T ≪ K·C (e.g. FC layers at batch 1).
//!
//! The mapper reports, per strategy, the weight-reprogramming work and the
//! resulting schedule overhead so the ablation can rank them per layer.

use crate::arch::core::Core;
use crate::dnn::layer::GemmShape;
use crate::optics::link_budget::ArchClass;

/// Tile iteration order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mapping {
    /// Weight block held; inputs stream over T (paper Fig. 1 default).
    WeightStationary,
    /// Output tile held; K-chunks iterate innermost (BPCA-friendly).
    OutputStationary,
    /// Input rows held; weight tiles cycle (FC/batch-1 special case).
    InputStationary,
}

impl Mapping {
    /// All strategies.
    pub const ALL: [Mapping; 3] =
        [Mapping::WeightStationary, Mapping::OutputStationary, Mapping::InputStationary];

    /// Short label for reports.
    pub fn name(self) -> &'static str {
        match self {
            Mapping::WeightStationary => "weight-stationary",
            Mapping::OutputStationary => "output-stationary",
            Mapping::InputStationary => "input-stationary",
        }
    }
}

/// Cost report for mapping one GEMM on one core design.
#[derive(Debug, Clone, Copy)]
pub struct MappingCost {
    /// Strategy evaluated.
    pub mapping: Mapping,
    /// Weight values written to MRR banks over the GEMM.
    pub weight_writes: u64,
    /// Cycles stalled for weight reprogramming (banks reload serially
    /// through the shared weight-update DACs).
    pub reload_cycles: u64,
    /// Compute timesteps (same as the execution plan).
    pub compute_steps: u64,
    /// Intermediate SRAM round-trips *avoided* vs the naive order
    /// (output-stationary lets baseline TIA cores accumulate digitally
    /// without spilling per pass).
    pub sram_passes_avoided: u64,
}

impl MappingCost {
    /// Total schedule length including reload stalls.
    pub fn total_cycles(&self) -> u64 {
        self.compute_steps + self.reload_cycles
    }

    /// Fraction of cycles doing useful compute.
    pub fn compute_efficiency(&self) -> f64 {
        self.compute_steps as f64 / self.total_cycles().max(1) as f64
    }
}

/// Weight-update DACs available per core for bank reprogramming (shared,
/// slow path — not the per-symbol input DACs).
pub const WEIGHT_UPDATE_DACS: u64 = 32;

/// Evaluate a mapping strategy for `shape` on `core`.
pub fn evaluate(core: &Core, shape: &GemmShape, mapping: Mapping) -> MappingCost {
    let plan = core.plan_gemm(shape);
    let n = core.n as u64;
    let m = core.m as u64;
    let t = shape.t as u64;
    let g = shape.groups as u64;
    let k_chunks = shape.k.div_ceil(core.n) as u64;
    let c_tiles = shape.c.div_ceil(core.m) as u64;
    // Weight values per (K-chunk, C-tile) block. SPOGA banks hold nibble
    // pairs (2 rings per value per DPU); baselines hold INT4 slices
    // (4 slice cores × their banks) — both reduce to 2·N·M ring writes per
    // INT8 weight block.
    let block_values = 2 * n * m;

    let (blocks_loaded, sram_avoided) = match mapping {
        // Each weight block loaded exactly once; all T rows stream.
        Mapping::WeightStationary => (k_chunks * c_tiles * g, 0),
        // Same load count (K innermost per output tile); baselines skip the
        // per-pass intermediate spill for all but the final pass.
        Mapping::OutputStationary => {
            let avoided = if core.arch == ArchClass::Mwa {
                0 // SPOGA never spills anyway (BPCA accumulation)
            } else {
                t * c_tiles * g * k_chunks.saturating_sub(1) * m
            };
            (k_chunks * c_tiles * g, avoided)
        }
        // Weight blocks reload for every input-row block of M rows.
        Mapping::InputStationary => {
            let row_blocks = t.div_ceil(m).max(1);
            (k_chunks * c_tiles * g * row_blocks, 0)
        }
    };
    let weight_writes = blocks_loaded * block_values;
    let reload_cycles = weight_writes.div_ceil(WEIGHT_UPDATE_DACS);

    MappingCost {
        mapping,
        weight_writes,
        reload_cycles,
        compute_steps: plan.timesteps,
        sram_passes_avoided: sram_avoided,
    }
}

/// Pick the best strategy (max compute efficiency, SRAM savings as
/// tie-break) for `shape` on `core`.
pub fn best_mapping(core: &Core, shape: &GemmShape) -> MappingCost {
    Mapping::ALL
        .iter()
        .map(|&m| evaluate(core, shape, m))
        .max_by(|a, b| {
            a.compute_efficiency()
                .total_cmp(&b.compute_efficiency())
                .then(a.sram_passes_avoided.cmp(&b.sram_passes_avoided))
        })
        .expect("non-empty strategies")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::DataRate;

    fn spoga() -> Core {
        Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap()
    }

    fn holy() -> Core {
        Core::design(ArchClass::Maw, DataRate::Gs5, 10.0).unwrap()
    }

    fn conv_shape() -> GemmShape {
        // A convolution-like GEMM: large T, moderate K/C.
        GemmShape { t: 3136, k: 576, c: 128, groups: 1 }
    }

    fn fc_shape() -> GemmShape {
        GemmShape { t: 1, k: 2048, c: 1000, groups: 1 }
    }

    #[test]
    fn weight_stationary_wins_conv_layers() {
        let best = best_mapping(&spoga(), &conv_shape());
        assert_ne!(best.mapping, Mapping::InputStationary);
        // Streaming 3136 rows amortizes the weight loads almost fully.
        assert!(best.compute_efficiency() > 0.9, "{}", best.compute_efficiency());
    }

    #[test]
    fn input_stationary_matches_weight_stationary_for_batch1_fc() {
        // T=1: reloading per row block = loading once; both degenerate.
        let ws = evaluate(&spoga(), &fc_shape(), Mapping::WeightStationary);
        let is = evaluate(&spoga(), &fc_shape(), Mapping::InputStationary);
        assert_eq!(ws.weight_writes, is.weight_writes);
    }

    #[test]
    fn input_stationary_explodes_for_large_t() {
        let ws = evaluate(&spoga(), &conv_shape(), Mapping::WeightStationary);
        let is = evaluate(&spoga(), &conv_shape(), Mapping::InputStationary);
        assert!(is.weight_writes > 50 * ws.weight_writes);
        assert!(is.compute_efficiency() < ws.compute_efficiency());
    }

    #[test]
    fn output_stationary_saves_baseline_sram_only() {
        let sh = GemmShape { t: 64, k: 4 * holy().n, c: 32, groups: 1 };
        let base = evaluate(&holy(), &sh, Mapping::OutputStationary);
        assert!(base.sram_passes_avoided > 0);
        let sp = evaluate(&spoga(), &sh, Mapping::OutputStationary);
        assert_eq!(sp.sram_passes_avoided, 0); // nothing to save — no spills
    }

    #[test]
    fn reload_cycles_scale_with_writes() {
        let a = evaluate(&spoga(), &conv_shape(), Mapping::WeightStationary);
        assert_eq!(a.reload_cycles, a.weight_writes.div_ceil(WEIGHT_UPDATE_DACS));
        assert!(a.total_cycles() >= a.compute_steps);
    }

    #[test]
    fn grouped_layers_multiply_weight_loads() {
        let g1 = evaluate(&spoga(), &GemmShape { t: 100, k: 9, c: 1, groups: 1 }, Mapping::WeightStationary);
        let g16 = evaluate(&spoga(), &GemmShape { t: 100, k: 9, c: 1, groups: 16 }, Mapping::WeightStationary);
        assert_eq!(g16.weight_writes, 16 * g1.weight_writes);
    }

    #[test]
    fn best_mapping_is_deterministic() {
        let a = best_mapping(&holy(), &conv_shape());
        let b = best_mapping(&holy(), &conv_shape());
        assert_eq!(a.mapping, b.mapping);
    }
}
