//! Minimal timing helpers for the `harness = false` benches (criterion is
//! not in the offline vendored dependency set).

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    /// Iterations measured.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Minimum (best) seconds per iteration.
    pub min_s: f64,
    /// Maximum seconds per iteration.
    pub max_s: f64,
}

impl BenchStats {
    /// Iterations per second at the mean.
    pub fn per_second(&self) -> f64 {
        if self.mean_s > 0.0 {
            1.0 / self.mean_s
        } else {
            0.0
        }
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3} ms  (min {:.3}, max {:.3}, n={})",
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut min_s = f64::INFINITY;
    let mut max_s: f64 = 0.0;
    let mut total = 0.0;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        min_s = min_s.min(dt);
        max_s = max_s.max(dt);
    }
    BenchStats { iters: iters.max(1), mean_s: total / iters.max(1) as f64, min_s, max_s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench(1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert_eq!(s.iters, 10);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s);
        assert!(s.per_second() > 0.0);
    }

    #[test]
    fn zero_iters_clamped() {
        let s = bench(0, 0, || 1);
        assert_eq!(s.iters, 1);
    }
}
