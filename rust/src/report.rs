//! Plain-text table rendering shared by benches, examples and the CLI.
//!
//! No external dependencies; produces aligned, Markdown-ish tables that the
//! benches print next to the paper's published numbers.

/// A simple column-aligned text table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given header cells.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (must match the header length; padded/truncated if not).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let mut cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        cells.resize(self.header.len(), String::new());
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = widths[i] - c.chars().count();
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(pad));
                s.push_str(" |");
            }
            s
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a float with engineering-style precision for reports.
pub fn fmt_sig(v: f64, digits: usize) -> String {
    if v == 0.0 {
        return "0".to_string();
    }
    let mag = v.abs().log10().floor() as i32;
    if (-2..=5).contains(&mag) {
        let dec = (digits as i32 - 1 - mag).max(0) as usize;
        format!("{v:.dec$}")
    } else {
        format!("{v:.prec$e}", prec = digits.saturating_sub(1))
    }
}

/// Format a ratio as "12.3x".
pub fn fmt_ratio(v: f64) -> String {
    format!("{}x", fmt_sig(v, 3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["a", "1"]);
        t.row(vec!["longer-name", "22222"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines equal width.
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
    }

    #[test]
    fn short_rows_padded() {
        let mut t = Table::new(vec!["a", "b", "c"]);
        t.row(vec!["x"]);
        assert!(t.render().contains("| x |"));
    }

    #[test]
    fn fmt_sig_small_and_large() {
        assert_eq!(fmt_sig(0.0, 3), "0");
        assert_eq!(fmt_sig(1234.0, 3), "1234");
        assert!(fmt_sig(1.5e9, 3).contains('e'));
        assert_eq!(fmt_sig(0.012, 2), "0.012");
    }

    #[test]
    fn fmt_ratio_has_suffix() {
        assert!(fmt_ratio(14.4).ends_with('x'));
    }
}
