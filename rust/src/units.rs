//! Physical unit helpers shared by every photonic model in the crate.
//!
//! All optical powers are carried as `f64` in either **dBm** (log scale,
//! referenced to 1 mW) or **mW** (linear). Conversions live here so that the
//! link-budget math in [`crate::optics`] reads like the equations in the
//! paper's references ([1], [2], [12]).

/// Convert a power in dBm to milliwatts.
#[inline]
pub fn dbm_to_mw(dbm: f64) -> f64 {
    10f64.powf(dbm / 10.0)
}

/// Convert a power in milliwatts to dBm.
///
/// Returns `-inf` for `mw <= 0`, matching the physical meaning (no power).
#[inline]
pub fn mw_to_dbm(mw: f64) -> f64 {
    if mw <= 0.0 {
        f64::NEG_INFINITY
    } else {
        10.0 * mw.log10()
    }
}

/// Convert a linear power *ratio* to decibels.
#[inline]
pub fn ratio_to_db(ratio: f64) -> f64 {
    if ratio <= 0.0 {
        f64::NEG_INFINITY
    } else {
        10.0 * ratio.log10()
    }
}

/// Convert decibels to a linear power ratio.
#[inline]
pub fn db_to_ratio(db: f64) -> f64 {
    10f64.powf(db / 10.0)
}

/// Data rate (= symbol rate of the analog photonic core), in gigasamples/s.
///
/// The paper evaluates every architecture at 1, 5 and 10 GS/s; the variants
/// are suffixed `_1`, `_5`, `_10` (e.g. `SPOGA_10`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DataRate {
    /// 1 GS/s — one analog symbol per nanosecond.
    Gs1,
    /// 5 GS/s.
    Gs5,
    /// 10 GS/s.
    Gs10,
}

impl DataRate {
    /// All data rates evaluated in the paper, ascending.
    pub const ALL: [DataRate; 3] = [DataRate::Gs1, DataRate::Gs5, DataRate::Gs10];

    /// Numeric rate in gigasamples per second.
    #[inline]
    pub fn gs(self) -> f64 {
        match self {
            DataRate::Gs1 => 1.0,
            DataRate::Gs5 => 5.0,
            DataRate::Gs10 => 10.0,
        }
    }

    /// Samples per second (Hz).
    #[inline]
    pub fn hz(self) -> f64 {
        self.gs() * 1e9
    }

    /// Duration of one analog symbol/time-step, in seconds.
    #[inline]
    pub fn step_seconds(self) -> f64 {
        1.0 / self.hz()
    }

    /// Paper-style suffix ("1", "5", "10") used in variant names.
    pub fn suffix(self) -> &'static str {
        match self {
            DataRate::Gs1 => "1",
            DataRate::Gs5 => "5",
            DataRate::Gs10 => "10",
        }
    }
}

impl std::fmt::Display for DataRate {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} GS/s", self.gs())
    }
}

/// Seconds → nanoseconds.
#[inline]
pub fn s_to_ns(s: f64) -> f64 {
    s * 1e9
}

/// Milliwatts → watts.
#[inline]
pub fn mw_to_w(mw: f64) -> f64 {
    mw * 1e-3
}

/// Joules per op at a given power (W) and rate (ops/s).
#[inline]
pub fn energy_per_op_j(power_w: f64, ops_per_s: f64) -> f64 {
    if ops_per_s <= 0.0 {
        0.0
    } else {
        power_w / ops_per_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn dbm_mw_roundtrip_at_reference_points() {
        assert!(close(dbm_to_mw(0.0), 1.0, 1e-12));
        assert!(close(dbm_to_mw(10.0), 10.0, 1e-9));
        assert!(close(dbm_to_mw(-30.0), 0.001, 1e-12));
        assert!(close(mw_to_dbm(1.0), 0.0, 1e-12));
        assert!(close(mw_to_dbm(100.0), 20.0, 1e-9));
    }

    #[test]
    fn dbm_mw_roundtrip_random_grid() {
        for i in -60..=30 {
            let dbm = i as f64 * 0.5;
            let back = mw_to_dbm(dbm_to_mw(dbm));
            assert!(close(back, dbm, 1e-9), "{dbm} -> {back}");
        }
    }

    #[test]
    fn mw_to_dbm_nonpositive_is_neg_inf() {
        assert_eq!(mw_to_dbm(0.0), f64::NEG_INFINITY);
        assert_eq!(mw_to_dbm(-1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn db_ratio_roundtrip() {
        for i in 0..50 {
            let db = i as f64 * 0.7 - 15.0;
            assert!(close(ratio_to_db(db_to_ratio(db)), db, 1e-9));
        }
    }

    #[test]
    fn three_db_is_factor_two() {
        assert!(close(db_to_ratio(3.0103), 2.0, 1e-3));
        assert!(close(ratio_to_db(0.5), -3.0103, 1e-3));
    }

    #[test]
    fn datarate_numeric_values() {
        assert_eq!(DataRate::Gs1.gs(), 1.0);
        assert_eq!(DataRate::Gs5.gs(), 5.0);
        assert_eq!(DataRate::Gs10.gs(), 10.0);
        assert_eq!(DataRate::Gs1.hz(), 1e9);
    }

    #[test]
    fn datarate_step_seconds_is_inverse_rate() {
        for dr in DataRate::ALL {
            assert!(close(dr.step_seconds() * dr.hz(), 1.0, 1e-12));
        }
    }

    #[test]
    fn datarate_ordering_matches_speed() {
        assert!(DataRate::Gs1 < DataRate::Gs5);
        assert!(DataRate::Gs5 < DataRate::Gs10);
    }

    #[test]
    fn datarate_suffixes_match_paper_naming() {
        assert_eq!(DataRate::Gs1.suffix(), "1");
        assert_eq!(DataRate::Gs5.suffix(), "5");
        assert_eq!(DataRate::Gs10.suffix(), "10");
    }

    #[test]
    fn energy_per_op_basic() {
        // 1 W at 1e9 ops/s = 1 nJ/op.
        assert!(close(energy_per_op_j(1.0, 1e9), 1e-9, 1e-18));
        assert_eq!(energy_per_op_j(1.0, 0.0), 0.0);
    }
}
