//! TCP shard server: fronts a local [`Coordinator`] (or a whole fleet) on a
//! socket so remote [`super::RemoteShard`] clients can submit work.
//!
//! Threading model (all joined on [`ShardServer::shutdown`] — the same
//! join-on-shutdown discipline the fleet janitor follows):
//!
//! * one *accept* thread polls the nonblocking listener against a stop flag;
//! * one *reader* thread per connection decodes inbound frames;
//! * one short-lived *waiter* thread per submitted request blocks on the
//!   coordinator's response slot and writes the encoded reply back through a
//!   shared, mutex-serialized writer (replies may complete out of order —
//!   the `request_id` correlates them client-side).
//!
//! Failure policy: a corrupt or version-skewed inbound frame means the
//! stream can no longer be trusted (framing may be desynchronized), so the
//! server closes that connection — the client reconnects and resubmits.
//! Request-level failures (unknown artifact, shape mismatch, shard down)
//! travel back as typed error replies instead.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use crate::coordinator::{CoordinatorHandle, FleetHandle, Qos, Reply, Response, RetryingSlot};
use crate::dnn::models::CnnModel;
use crate::metrics::ShardTelemetry;
use crate::sync::lock_recovered;
use crate::{Error, Result};

use super::wire::{self, Frame, Opcode};
use super::{configure_stream, NetConfig, PollRead, POLL_SLICE};

/// What a [`ShardServer`] serves: one coordinator, or a whole local fleet
/// (in which case the server's *internal* failover runs before a failure
/// ever crosses the wire — only a fleet-exhausted `ShardDown` reaches the
/// client, which is exactly when the client should fail over elsewhere).
pub enum ServeTarget {
    /// Serve a single coordinator.
    Coordinator(CoordinatorHandle),
    /// Serve a fleet handle; submits use retained-payload retrying.
    Fleet(FleetHandle),
}

/// In-flight server-side request: either a plain response slot or a
/// fleet retrying slot (which resubmits internally on shard death).
enum InFlight {
    Slot(Response),
    Retry(RetryingSlot),
}

impl InFlight {
    fn wait(self) -> Result<Reply> {
        match self {
            InFlight::Slot(rx) => match rx.recv() {
                Ok(outcome) => outcome,
                Err(_) => Err(Error::Coordinator(
                    "response dropped (worker crashed mid-request?)".into(),
                )),
            },
            InFlight::Retry(slot) => slot.recv(),
        }
    }
}

impl ServeTarget {
    // The decoded deadline is *relative* (time remaining when the client
    // encoded it); the coordinator re-anchors it at its own enqueue instant,
    // so wire transit time is charged to the client's margin, not the job's.
    fn submit_gemm(&self, artifact: &str, a: Vec<i32>, b: Vec<i32>, qos: Qos) -> Result<InFlight> {
        match self {
            ServeTarget::Coordinator(h) => {
                h.submit_gemm_qos(artifact, a, b, qos).map(InFlight::Slot)
            }
            ServeTarget::Fleet(f) => {
                f.submit_gemm_retrying_qos(artifact, a, b, qos).map(InFlight::Retry)
            }
        }
    }

    fn submit_mlp(&self, row: Vec<i32>, qos: Qos) -> Result<InFlight> {
        match self {
            ServeTarget::Coordinator(h) => h.submit_mlp_qos(row, qos).map(InFlight::Slot),
            ServeTarget::Fleet(f) => f.submit_mlp_retrying_qos(row, qos).map(InFlight::Retry),
        }
    }

    fn submit_cnn(&self, model: CnnModel, input: Vec<i32>, qos: Qos) -> Result<InFlight> {
        match self {
            ServeTarget::Coordinator(h) => h.submit_cnn_qos(model, input, qos).map(InFlight::Slot),
            ServeTarget::Fleet(f) => {
                f.submit_cnn_retrying_qos(model, input, qos).map(InFlight::Retry)
            }
        }
    }

    fn ping(&self, timeout: Duration) -> Result<()> {
        match self {
            ServeTarget::Coordinator(h) => h.ping(timeout),
            ServeTarget::Fleet(f) => f.ping(timeout),
        }
    }

    /// One telemetry snapshot for the whole target. A fleet rolls its shards
    /// up into a single pseudo-shard so the wire format stays uniform.
    fn telemetry(&self) -> ShardTelemetry {
        match self {
            ServeTarget::Coordinator(h) => ShardTelemetry::capture("served", h.stats()),
            ServeTarget::Fleet(f) => {
                let t = f.telemetry();
                let mut roll = ShardTelemetry {
                    label: format!("fleet({} shards)", t.shards.len()),
                    ..ShardTelemetry::default()
                };
                for s in &t.shards {
                    roll.requests += s.requests;
                    roll.completed += s.completed;
                    roll.failed += s.failed;
                    roll.batches += s.batches;
                    roll.cnn_frames += s.cnn_frames;
                    roll.cnn_batches += s.cnn_batches;
                    roll.sim_reports += s.sim_reports;
                    roll.sim_latency_s += s.sim_latency_s;
                    roll.energy_j += s.energy_j;
                    roll.lanes += s.lanes;
                    roll.noise_events += s.noise_events;
                    roll.live_workers += s.live_workers;
                    roll.revivals += s.revivals;
                    roll.shed += s.shed;
                    roll.shed_best_effort += s.shed_best_effort;
                    roll.deadline_expired += s.deadline_expired;
                }
                roll
            }
        }
    }
}

struct ServerInner {
    target: ServeTarget,
    cfg: NetConfig,
    listener: TcpListener,
    stop: AtomicBool,
    /// Parsed-model cache keyed by trace text: `parse_trace` leaks one name
    /// string per distinct model, which this cache amortizes to once.
    models: Mutex<HashMap<String, CnnModel>>,
}

/// TCP front for a [`ServeTarget`]. Bind with [`ShardServer::start`]; stop
/// with [`ShardServer::shutdown`] (joins every spawned thread).
pub struct ShardServer {
    inner: Arc<ServerInner>,
    local_addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl ShardServer {
    /// Bind `listen` (e.g. `127.0.0.1:0` for an OS-assigned port) and start
    /// accepting connections.
    pub fn start(listen: &str, target: ServeTarget, cfg: NetConfig) -> Result<ShardServer> {
        let listener = TcpListener::bind(listen)
            .map_err(|e| Error::Config(format!("bind {listen}: {e}")))?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let inner = Arc::new(ServerInner {
            target,
            cfg,
            listener,
            stop: AtomicBool::new(false),
            models: Mutex::new(HashMap::new()),
        });
        let accept = {
            let inner = inner.clone();
            thread::Builder::new()
                .name(format!("spoga-accept@{local_addr}"))
                .spawn(move || accept_loop(inner))
                .map_err(|e| Error::Runtime(format!("spawn accept thread: {e}")))?
        };
        Ok(ShardServer { inner, local_addr, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the OS-assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Whether a peer requested shutdown (the [`Opcode::Shutdown`] frame) or
    /// [`ShardServer::request_stop`] ran. The CLI serve loop polls this.
    pub fn stop_requested(&self) -> bool {
        self.inner.stop.load(Relaxed)
    }

    /// Ask the accept loop to wind down without joining yet.
    pub fn request_stop(&self) {
        self.inner.stop.store(true, Relaxed);
    }

    /// Stop accepting, close the listener, and join the accept thread (which
    /// in turn joins every connection and waiter thread it spawned).
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.inner.stop.store(true, Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        self.halt();
    }
}

fn accept_loop(inner: Arc<ServerInner>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !inner.stop.load(Relaxed) {
        match inner.listener.accept() {
            Ok((stream, peer)) => {
                if configure_stream(&stream, &inner.cfg).is_err() {
                    continue; // peer vanished between accept and setup
                }
                let inner2 = inner.clone();
                if let Ok(h) = thread::Builder::new()
                    .name(format!("spoga-conn@{peer}"))
                    .spawn(move || handle_conn(inner2, stream))
                {
                    conns.push(h);
                }
                conns.retain(|h| !h.is_finished());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(POLL_SLICE);
            }
            Err(_) => thread::sleep(POLL_SLICE), // transient accept failure
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

fn handle_conn(inner: Arc<ServerInner>, stream: TcpStream) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut waiters: Vec<JoinHandle<()>> = Vec::new();
    loop {
        let mut poll =
            PollRead { stream: &stream, keep_going: || !inner.stop.load(Relaxed) };
        match wire::read_frame(&mut poll, inner.cfg.max_frame_len) {
            Ok(frame) => {
                if !dispatch(&inner, frame, &writer, &mut waiters) {
                    break;
                }
                waiters.retain(|h| !h.is_finished());
            }
            // Timeout here only means the stop flag tripped mid-idle; any
            // other failure (corrupt frame, version skew, EOF) means the
            // stream cannot be trusted or the peer is gone — close it and
            // let the client reconnect with clean framing.
            Err(_) => break,
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
    for h in waiters {
        let _ = h.join();
    }
}

/// Handle one inbound frame. Returns false when the connection (or the whole
/// server, on [`Opcode::Shutdown`]) should wind down.
fn dispatch(
    inner: &Arc<ServerInner>,
    frame: Frame,
    writer: &Arc<Mutex<TcpStream>>,
    waiters: &mut Vec<JoinHandle<()>>,
) -> bool {
    let id = frame.request_id;
    match frame.opcode {
        Opcode::SubmitGemm => {
            let submitted = wire::decode_gemm(&frame.payload)
                .and_then(|(artifact, a, b, qos)| inner.target.submit_gemm(&artifact, a, b, qos));
            spawn_reply_waiter(submitted, id, writer, waiters);
        }
        Opcode::SubmitMlp => {
            let submitted = wire::decode_mlp(&frame.payload)
                .and_then(|(row, qos)| inner.target.submit_mlp(row, qos));
            spawn_reply_waiter(submitted, id, writer, waiters);
        }
        Opcode::SubmitCnn => {
            let submitted = wire::decode_cnn(&frame.payload).and_then(|(trace, input, qos)| {
                let model = cached_model(inner, &trace)?;
                inner.target.submit_cnn(model, input, qos)
            });
            spawn_reply_waiter(submitted, id, writer, waiters);
        }
        Opcode::Ping => {
            let inner2 = inner.clone();
            let writer2 = writer.clone();
            spawn_waiter(waiters, "spoga-pong", move || {
                match inner2.target.ping(inner2.cfg.io_timeout) {
                    Ok(()) => write_back(&writer2, &Frame::control(Opcode::Pong, id)),
                    Err(e) => write_reply(&writer2, id, &Err(e)),
                }
            });
        }
        Opcode::Stats => {
            let snapshot = inner.target.telemetry();
            write_back(
                writer,
                &Frame { opcode: Opcode::Stats, request_id: id, payload: wire::encode_stats(&snapshot) },
            );
        }
        Opcode::Shutdown => {
            inner.stop.store(true, Relaxed);
            return false;
        }
        // Server-bound streams never carry these; ignore rather than kill
        // the connection (they framed correctly, so framing is intact).
        Opcode::Reply | Opcode::Pong => {}
    }
    true
}

/// Look up (or parse-and-cache) the model for a trace text. The cache bounds
/// `parse_trace`'s per-distinct-model name leak to once per model.
fn cached_model(inner: &ServerInner, trace: &str) -> Result<CnnModel> {
    let mut cache = lock_recovered(&inner.models);
    if let Some(m) = cache.get(trace) {
        return Ok(m.clone());
    }
    let model = wire::cnn_from_trace(trace)?;
    cache.insert(trace.to_string(), model.clone());
    Ok(model)
}

/// Spawn a waiter that resolves `submitted` and writes the reply frame. A
/// submit-time error still answers the client (typed error reply) — silence
/// would make the client burn its full `io_timeout` for a known failure.
fn spawn_reply_waiter(
    submitted: Result<InFlight>,
    id: u64,
    writer: &Arc<Mutex<TcpStream>>,
    waiters: &mut Vec<JoinHandle<()>>,
) {
    let writer = writer.clone();
    match submitted {
        Ok(inflight) => spawn_waiter(waiters, "spoga-reply", move || {
            let outcome = inflight.wait();
            write_reply(&writer, id, &outcome);
        }),
        Err(e) => write_reply(&writer, id, &Err(e)),
    }
}

fn spawn_waiter(waiters: &mut Vec<JoinHandle<()>>, name: &str, f: impl FnOnce() + Send + 'static) {
    if let Ok(h) = thread::Builder::new().name(name.to_string()).spawn(f) {
        waiters.push(h);
    }
}

fn write_reply(writer: &Arc<Mutex<TcpStream>>, id: u64, outcome: &Result<Reply>) {
    write_back(
        writer,
        &Frame { opcode: Opcode::Reply, request_id: id, payload: wire::encode_reply(outcome) },
    );
}

/// Write one frame through the shared writer. Errors are swallowed: a dead
/// connection is detected (and torn down) by the reader side.
fn write_back(writer: &Arc<Mutex<TcpStream>>, frame: &Frame) {
    let mut w = lock_recovered(writer);
    let _ = wire::write_frame(&mut *w, frame);
}
