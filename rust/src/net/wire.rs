//! Length-prefixed, checksummed binary framing for cross-host serving.
//!
//! Zero-dependency discipline: frames travel over std `TcpStream`s and are
//! encoded by hand (no serde). Every frame is
//!
//! ```text
//! magic      u32  "SPOG" (little-endian byte order throughout)
//! version    u16  wire-protocol version (VERSION)
//! opcode     u8   Opcode discriminant
//! reserved   u8   0 (future flags; checksummed so it cannot drift silently)
//! request_id u64  correlates replies with in-flight submits
//! payload_len u32 bytes of payload that follow the header
//! checksum   u64  FNV-1a over version..payload_len header bytes + payload
//! payload    [u8; payload_len]
//! ```
//!
//! [`read_frame`] maps every failure onto the typed
//! [`crate::error::RemoteErrorKind`] taxonomy: garbage magic / bad checksum /
//! oversized length → `FrameCorrupt`, foreign version → `VersionMismatch`,
//! EOF or reset → `PeerGone`, an expired socket deadline → `Timeout`. The
//! caller (not this module) decides which kinds retire a shard — see
//! [`crate::error::RemoteErrorKind::retires_shard`].

use std::io::{Read, Write};

use std::time::Duration;

use crate::dnn::trace::{parse_trace, to_trace};
use crate::dnn::models::CnnModel;
use crate::error::RemoteErrorKind;
use crate::metrics::ShardTelemetry;
use crate::runtime::backend::ExecReport;
use crate::runtime::cnnrun::LayerReport;
use crate::coordinator::{Priority, Qos, Reply};
use crate::{Error, Result};

/// Frame magic: `b"SPOG"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"SPOG");
/// Wire-protocol version. Bump on any layout change.
/// v2: submit payloads carry a QoS envelope (priority class + deadline),
/// error codecs know `Overloaded`/`DeadlineExceeded`, stats snapshots carry
/// the shed/deadline counters.
pub const VERSION: u16 = 2;
/// Bytes in the fixed frame header.
pub const HEADER_LEN: usize = 28;

/// Frame opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Opcode {
    /// Client → server: raw GEMM against a named artifact.
    SubmitGemm = 1,
    /// Client → server: single-row MLP inference.
    SubmitMlp = 2,
    /// Client → server: whole-CNN inference (model ships as trace text).
    SubmitCnn = 3,
    /// Server → client: result for the identified request.
    Reply = 4,
    /// Client → server: end-to-end health probe (routed through the pool).
    Ping = 5,
    /// Server → client: answer to [`Opcode::Ping`].
    Pong = 6,
    /// Client → server: stats request; server answers with the same opcode
    /// carrying a [`ShardTelemetry`] snapshot.
    Stats = 7,
    /// Client → server: stop accepting connections and exit the serve loop.
    Shutdown = 8,
}

impl Opcode {
    fn from_u8(v: u8) -> Option<Opcode> {
        Some(match v {
            1 => Opcode::SubmitGemm,
            2 => Opcode::SubmitMlp,
            3 => Opcode::SubmitCnn,
            4 => Opcode::Reply,
            5 => Opcode::Ping,
            6 => Opcode::Pong,
            7 => Opcode::Stats,
            8 => Opcode::Shutdown,
            _ => return None,
        })
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// What this frame carries.
    pub opcode: Opcode,
    /// Request correlation id (0 for control frames that need none).
    pub request_id: u64,
    /// Opcode-specific payload bytes (see the `encode_*`/`decode_*` pairs).
    pub payload: Vec<u8>,
}

impl Frame {
    /// A payload-free control frame.
    pub fn control(opcode: Opcode, request_id: u64) -> Frame {
        Frame { opcode, request_id, payload: Vec::new() }
    }
}

/// FNV-1a over a byte slice, continuing from `h` (seed with [`FNV_OFFSET`]).
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01B3;

/// Fold bytes into a running FNV-1a hash.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(FNV_PRIME);
    }
    h
}

fn frame_checksum(version: u16, opcode: u8, reserved: u8, request_id: u64, payload: &[u8]) -> u64 {
    let mut h = fnv1a(FNV_OFFSET, &version.to_le_bytes());
    h = fnv1a(h, &[opcode, reserved]);
    h = fnv1a(h, &request_id.to_le_bytes());
    h = fnv1a(h, &(payload.len() as u32).to_le_bytes());
    fnv1a(h, payload)
}

/// Remote-error constructor shorthand.
pub(crate) fn remote_err(kind: RemoteErrorKind, detail: impl Into<String>) -> Error {
    Error::Remote { kind, detail: detail.into() }
}

/// Classify an I/O failure during a frame read/write into the remote
/// taxonomy: deadline expiry is `Timeout`; everything else means the
/// connection is no longer usable (`PeerGone`).
pub fn classify_io(e: &std::io::Error, what: &str) -> Error {
    use std::io::ErrorKind::*;
    let kind = match e.kind() {
        WouldBlock | TimedOut => RemoteErrorKind::Timeout,
        ConnectionRefused => RemoteErrorKind::ConnRefused,
        _ => RemoteErrorKind::PeerGone,
    };
    remote_err(kind, format!("{what}: {e}"))
}

/// Serialize and write one frame.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<()> {
    let opcode = frame.opcode as u8;
    let checksum = frame_checksum(VERSION, opcode, 0, frame.request_id, &frame.payload);
    let mut buf = Vec::with_capacity(HEADER_LEN + frame.payload.len());
    buf.extend_from_slice(&MAGIC.to_le_bytes());
    buf.extend_from_slice(&VERSION.to_le_bytes());
    buf.push(opcode);
    buf.push(0); // reserved
    buf.extend_from_slice(&frame.request_id.to_le_bytes());
    buf.extend_from_slice(&(frame.payload.len() as u32).to_le_bytes());
    buf.extend_from_slice(&checksum.to_le_bytes());
    buf.extend_from_slice(&frame.payload);
    w.write_all(&buf).map_err(|e| classify_io(&e, "write frame"))?;
    w.flush().map_err(|e| classify_io(&e, "flush frame"))?;
    Ok(())
}

/// Read and validate one frame. `max_frame_len` bounds the payload a peer
/// may make us allocate (a corrupt or hostile length field must not OOM the
/// process).
pub fn read_frame(r: &mut impl Read, max_frame_len: usize) -> Result<Frame> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header).map_err(|e| classify_io(&e, "read frame header"))?;
    let magic = u32::from_le_bytes(header[0..4].try_into().unwrap());
    if magic != MAGIC {
        return Err(remote_err(
            RemoteErrorKind::FrameCorrupt,
            format!("bad magic {magic:#010x} (stream desynchronized)"),
        ));
    }
    let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
    if version != VERSION {
        return Err(remote_err(
            RemoteErrorKind::VersionMismatch,
            format!("peer speaks wire v{version}, this build speaks v{VERSION}"),
        ));
    }
    let opcode_raw = header[6];
    let reserved = header[7];
    let request_id = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let payload_len = u32::from_le_bytes(header[16..20].try_into().unwrap()) as usize;
    let checksum = u64::from_le_bytes(header[20..28].try_into().unwrap());
    if payload_len > max_frame_len {
        return Err(remote_err(
            RemoteErrorKind::FrameCorrupt,
            format!("payload length {payload_len} exceeds max_frame_len {max_frame_len}"),
        ));
    }
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload).map_err(|e| classify_io(&e, "read frame payload"))?;
    let expect = frame_checksum(version, opcode_raw, reserved, request_id, &payload);
    if checksum != expect {
        return Err(remote_err(
            RemoteErrorKind::FrameCorrupt,
            format!("checksum mismatch (got {checksum:#018x}, want {expect:#018x})"),
        ));
    }
    let opcode = Opcode::from_u8(opcode_raw).ok_or_else(|| {
        remote_err(RemoteErrorKind::FrameCorrupt, format!("unknown opcode {opcode_raw}"))
    })?;
    Ok(Frame { opcode, request_id, payload })
}

// ---------------------------------------------------------------------------
// Payload codecs — little-endian, length-prefixed, hand-rolled.
// ---------------------------------------------------------------------------

/// Growable payload encoder.
#[derive(Default)]
pub struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    /// Fresh empty payload.
    pub fn new() -> Self {
        Self::default()
    }

    /// Finish and take the encoded bytes.
    pub fn finish(self) -> Vec<u8> {
        self.buf
    }

    fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn put_str(&mut self, s: &str) {
        self.put_u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }
    fn put_vec_i32(&mut self, v: &[i32]) {
        self.put_u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn put_vec_u64(&mut self, v: &[u64]) {
        self.put_u32(v.len() as u32);
        for x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

/// Cursor-based payload decoder; every `take_*` fails with `FrameCorrupt`
/// on truncation (the frame checksum already passed, so truncation here
/// means an encoder bug or a forged frame, never line noise).
pub struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    /// Decode from payload bytes.
    pub fn new(buf: &'a [u8]) -> Self {
        PayloadReader { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(remote_err(
                RemoteErrorKind::FrameCorrupt,
                format!("payload truncated at byte {} (need {n} more)", self.pos),
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn take_u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn take_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn take_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn take_f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }
    fn take_str(&mut self) -> Result<String> {
        let n = self.take_u32()? as usize;
        let s = self.bytes(n)?;
        String::from_utf8(s.to_vec())
            .map_err(|_| remote_err(RemoteErrorKind::FrameCorrupt, "non-utf8 string field"))
    }
    fn take_vec_i32(&mut self) -> Result<Vec<i32>> {
        let n = self.take_u32()? as usize;
        let raw = self.bytes(n.checked_mul(4).ok_or_else(|| {
            remote_err(RemoteErrorKind::FrameCorrupt, "i32 vector length overflow")
        })?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }
    fn take_vec_u64(&mut self) -> Result<Vec<u64>> {
        let n = self.take_u32()? as usize;
        let raw = self.bytes(n.checked_mul(8).ok_or_else(|| {
            remote_err(RemoteErrorKind::FrameCorrupt, "u64 vector length overflow")
        })?)?;
        Ok(raw.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect())
    }
}

// QoS envelope codec (v2): one priority byte (0 = High, 1 = BestEffort)
// plus the deadline in whole microseconds (0 = none; a sub-microsecond
// deadline clamps up to 1 µs rather than silently becoming "no deadline").
// The deadline crosses the wire *relative* — the server re-anchors it at
// its own enqueue instant, so clock skew between peers never expires a
// request spuriously (socket transit time is simply part of the budget the
// caller chose).
fn encode_qos(w: &mut PayloadWriter, qos: &Qos) {
    w.put_u8(match qos.priority {
        Priority::High => 0,
        Priority::BestEffort => 1,
    });
    w.put_u64(match qos.deadline {
        None => 0,
        Some(d) => (d.as_micros() as u64).max(1),
    });
}

fn decode_qos(r: &mut PayloadReader<'_>) -> Result<Qos> {
    let priority = match r.take_u8()? {
        0 => Priority::High,
        1 => Priority::BestEffort,
        p => {
            return Err(remote_err(
                RemoteErrorKind::FrameCorrupt,
                format!("unknown priority byte {p}"),
            ))
        }
    };
    let deadline_us = r.take_u64()?;
    Ok(Qos {
        priority,
        deadline: (deadline_us > 0).then(|| Duration::from_micros(deadline_us)),
    })
}

/// Encode a GEMM submit: artifact name + both operands + QoS envelope.
pub fn encode_gemm(artifact: &str, a: &[i32], b: &[i32], qos: &Qos) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_str(artifact);
    w.put_vec_i32(a);
    w.put_vec_i32(b);
    encode_qos(&mut w, qos);
    w.finish()
}

/// Decode a GEMM submit.
pub fn decode_gemm(payload: &[u8]) -> Result<(String, Vec<i32>, Vec<i32>, Qos)> {
    let mut r = PayloadReader::new(payload);
    Ok((r.take_str()?, r.take_vec_i32()?, r.take_vec_i32()?, decode_qos(&mut r)?))
}

/// Encode an MLP submit: one activation row + QoS envelope.
pub fn encode_mlp(row: &[i32], qos: &Qos) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_vec_i32(row);
    encode_qos(&mut w, qos);
    w.finish()
}

/// Decode an MLP submit.
pub fn decode_mlp(payload: &[u8]) -> Result<(Vec<i32>, Qos)> {
    let mut r = PayloadReader::new(payload);
    Ok((r.take_vec_i32()?, decode_qos(&mut r)?))
}

/// Encode a CNN submit. The model crosses the wire as trace text
/// ([`to_trace`]) — the one textual model format the repo already
/// round-trips — so the server rebuilds an identical [`CnnModel`] with
/// [`parse_trace`]. Servers should cache parsed models per trace text:
/// `parse_trace` leaks one small name string per *distinct* model (the
/// `&'static str` name convention), which a cache amortizes to once.
pub fn encode_cnn(model: &CnnModel, input: &[i32], qos: &Qos) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_str(&to_trace(model));
    w.put_vec_i32(input);
    encode_qos(&mut w, qos);
    w.finish()
}

/// Decode a CNN submit into (trace text, input, qos). The caller decides
/// when to pay the `parse_trace` name leak (see [`encode_cnn`]).
pub fn decode_cnn(payload: &[u8]) -> Result<(String, Vec<i32>, Qos)> {
    let mut r = PayloadReader::new(payload);
    Ok((r.take_str()?, r.take_vec_i32()?, decode_qos(&mut r)?))
}

/// Parse the trace text from [`decode_cnn`] back into a model.
pub fn cnn_from_trace(trace: &str) -> Result<CnnModel> {
    parse_trace(trace)
}

fn encode_report(w: &mut PayloadWriter, r: &ExecReport) {
    w.put_f64(r.sim_latency_s);
    w.put_f64(r.energy_j);
    w.put_u64(r.lanes);
    w.put_u64(r.noise_events);
    w.put_vec_u64(&r.row_noise);
}

fn decode_report(r: &mut PayloadReader<'_>) -> Result<ExecReport> {
    Ok(ExecReport {
        sim_latency_s: r.take_f64()?,
        energy_j: r.take_f64()?,
        lanes: r.take_u64()?,
        noise_events: r.take_u64()?,
        row_noise: r.take_vec_u64()?,
    })
}

// Error wire tags. Io flattens to Runtime on decode (io::Error does not
// round-trip); everything else rebuilds its own variant so failover
// semantics survive the hop — a server-side ShardDown must arrive as
// ShardDown for the client fleet to fail over.
fn encode_error(w: &mut PayloadWriter, e: &Error) {
    let (tag, kind, msg): (u8, u8, String) = match e {
        Error::Infeasible(m) => (0, 0, m.clone()),
        Error::Config(m) => (1, 0, m.clone()),
        Error::Shape(m) => (2, 0, m.clone()),
        Error::Artifact(m) => (3, 0, m.clone()),
        Error::Runtime(m) => (4, 0, m.clone()),
        Error::Coordinator(m) => (5, 0, m.clone()),
        Error::ShardDown(m) => (6, 0, m.clone()),
        Error::Remote { kind, detail } => {
            let k = match kind {
                RemoteErrorKind::Timeout => 0,
                RemoteErrorKind::ConnRefused => 1,
                RemoteErrorKind::FrameCorrupt => 2,
                RemoteErrorKind::VersionMismatch => 3,
                RemoteErrorKind::PeerGone => 4,
            };
            (7, k, detail.clone())
        }
        Error::Io(e) => (8, 0, e.to_string()),
        // QoS refusals must survive the hop typed: a client-side router
        // treats Overloaded as busy-not-dead and DeadlineExceeded as the
        // caller's own budget — flattening either to a generic error would
        // turn admission shedding into failover storms.
        Error::Overloaded(m) => (9, 0, m.clone()),
        Error::DeadlineExceeded(m) => (10, 0, m.clone()),
    };
    w.put_u8(tag);
    w.put_u8(kind);
    w.put_str(&msg);
}

fn decode_error(r: &mut PayloadReader<'_>) -> Result<Error> {
    let tag = r.take_u8()?;
    let kind = r.take_u8()?;
    let msg = r.take_str()?;
    Ok(match tag {
        0 => Error::Infeasible(msg),
        1 => Error::Config(msg),
        2 => Error::Shape(msg),
        3 => Error::Artifact(msg),
        4 | 8 => Error::Runtime(msg),
        5 => Error::Coordinator(msg),
        6 => Error::ShardDown(msg),
        9 => Error::Overloaded(msg),
        10 => Error::DeadlineExceeded(msg),
        7 => {
            let k = match kind {
                0 => RemoteErrorKind::Timeout,
                1 => RemoteErrorKind::ConnRefused,
                2 => RemoteErrorKind::FrameCorrupt,
                3 => RemoteErrorKind::VersionMismatch,
                4 => RemoteErrorKind::PeerGone,
                _ => {
                    return Err(remote_err(
                        RemoteErrorKind::FrameCorrupt,
                        format!("unknown remote-error kind {kind}"),
                    ))
                }
            };
            Error::Remote { kind: k, detail: msg }
        }
        _ => {
            return Err(remote_err(
                RemoteErrorKind::FrameCorrupt,
                format!("unknown error tag {tag}"),
            ))
        }
    })
}

/// Encode a request outcome (the payload of an [`Opcode::Reply`] frame).
pub fn encode_reply(outcome: &Result<Reply>) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    match outcome {
        Ok(reply) => {
            w.put_u8(0);
            w.put_vec_i32(&reply.outputs);
            match &reply.report {
                Some(r) => {
                    w.put_u8(1);
                    encode_report(&mut w, r);
                }
                None => w.put_u8(0),
            }
            w.put_u32(reply.layers.len() as u32);
            for l in &reply.layers {
                w.put_str(&l.layer);
                encode_report(&mut w, &l.report);
            }
        }
        Err(e) => {
            w.put_u8(1);
            encode_error(&mut w, e);
        }
    }
    w.finish()
}

/// Decode a request outcome.
pub fn decode_reply(payload: &[u8]) -> Result<Result<Reply>> {
    let mut r = PayloadReader::new(payload);
    match r.take_u8()? {
        0 => {
            let outputs = r.take_vec_i32()?;
            let report = match r.take_u8()? {
                0 => None,
                _ => Some(decode_report(&mut r)?),
            };
            let n = r.take_u32()? as usize;
            let mut layers = Vec::with_capacity(n.min(4096));
            for _ in 0..n {
                layers.push(LayerReport { layer: r.take_str()?, report: decode_report(&mut r)? });
            }
            Ok(Ok(Reply { outputs, report, layers }))
        }
        1 => Ok(Err(decode_error(&mut r)?)),
        t => Err(remote_err(RemoteErrorKind::FrameCorrupt, format!("unknown reply tag {t}"))),
    }
}

/// Encode a [`ShardTelemetry`] snapshot (the payload of a server-side
/// [`Opcode::Stats`] reply).
pub fn encode_stats(t: &ShardTelemetry) -> Vec<u8> {
    let mut w = PayloadWriter::new();
    w.put_str(&t.label);
    w.put_u64(t.requests);
    w.put_u64(t.completed);
    w.put_u64(t.failed);
    w.put_u64(t.batches);
    w.put_u64(t.cnn_frames);
    w.put_u64(t.cnn_batches);
    w.put_u64(t.sim_reports);
    w.put_f64(t.sim_latency_s);
    w.put_f64(t.energy_j);
    w.put_u64(t.lanes);
    w.put_u64(t.noise_events);
    w.put_u64(t.live_workers);
    w.put_u64(t.revivals);
    w.put_u64(t.shed);
    w.put_u64(t.shed_best_effort);
    w.put_u64(t.deadline_expired);
    w.finish()
}

/// Decode a [`ShardTelemetry`] snapshot.
pub fn decode_stats(payload: &[u8]) -> Result<ShardTelemetry> {
    let mut r = PayloadReader::new(payload);
    Ok(ShardTelemetry {
        label: r.take_str()?,
        requests: r.take_u64()?,
        completed: r.take_u64()?,
        failed: r.take_u64()?,
        batches: r.take_u64()?,
        cnn_frames: r.take_u64()?,
        cnn_batches: r.take_u64()?,
        sim_reports: r.take_u64()?,
        sim_latency_s: r.take_f64()?,
        energy_j: r.take_f64()?,
        lanes: r.take_u64()?,
        noise_events: r.take_u64()?,
        live_workers: r.take_u64()?,
        revivals: r.take_u64()?,
        shed: r.take_u64()?,
        shed_best_effort: r.take_u64()?,
        deadline_expired: r.take_u64()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dnn::layer::Layer;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut buf.as_slice(), 1 << 20).unwrap()
    }

    #[test]
    fn frame_roundtrips() {
        let f = Frame { opcode: Opcode::SubmitMlp, request_id: 42, payload: vec![1, 2, 3] };
        assert_eq!(roundtrip(&f), f);
        let c = Frame::control(Opcode::Ping, 7);
        assert_eq!(roundtrip(&c), c);
    }

    #[test]
    fn corrupt_byte_fails_checksum() {
        let f = Frame { opcode: Opcode::Reply, request_id: 9, payload: vec![5; 64] };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0xFF; // flip one payload byte
        let err = read_frame(&mut buf.as_slice(), 1 << 20).unwrap_err();
        assert!(
            matches!(err, Error::Remote { kind: RemoteErrorKind::FrameCorrupt, .. }),
            "got {err}"
        );
    }

    #[test]
    fn bad_magic_is_frame_corrupt() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::control(Opcode::Pong, 0)).unwrap();
        buf[0] = b'X';
        let err = read_frame(&mut buf.as_slice(), 1 << 20).unwrap_err();
        assert!(matches!(err, Error::Remote { kind: RemoteErrorKind::FrameCorrupt, .. }));
    }

    #[test]
    fn foreign_version_is_version_mismatch() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::control(Opcode::Pong, 0)).unwrap();
        buf[4] = 0xFE; // version low byte
        let err = read_frame(&mut buf.as_slice(), 1 << 20).unwrap_err();
        assert!(matches!(err, Error::Remote { kind: RemoteErrorKind::VersionMismatch, .. }));
    }

    #[test]
    fn truncated_stream_is_peer_gone() {
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame { opcode: Opcode::Reply, request_id: 1, payload: vec![0; 32] },
        )
        .unwrap();
        buf.truncate(buf.len() - 10);
        let err = read_frame(&mut buf.as_slice(), 1 << 20).unwrap_err();
        assert!(matches!(err, Error::Remote { kind: RemoteErrorKind::PeerGone, .. }));
    }

    #[test]
    fn oversized_length_is_bounded() {
        let f = Frame { opcode: Opcode::Reply, request_id: 1, payload: vec![0; 128] };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        let err = read_frame(&mut buf.as_slice(), 64).unwrap_err();
        assert!(matches!(err, Error::Remote { kind: RemoteErrorKind::FrameCorrupt, .. }));
    }

    #[test]
    fn submit_payloads_roundtrip() {
        let q = Qos::default();
        let (name, a, b, qos) =
            decode_gemm(&encode_gemm("gemm_8x8x8", &[1, -2], &[3], &q)).unwrap();
        assert_eq!((name.as_str(), a, b), ("gemm_8x8x8", vec![1, -2], vec![3]));
        assert_eq!(qos, Qos::default());
        let (row, qos) = decode_mlp(&encode_mlp(&[9, 8, -7], &q)).unwrap();
        assert_eq!(row, vec![9, 8, -7]);
        assert_eq!(qos, Qos::default());
        let model = CnnModel {
            name: "tiny",
            layers: vec![Layer::conv("stem", 4, 4, 1, 2, 3, 1, 1), Layer::fc("head", 32, 4)],
        };
        let (trace, input, _qos) = decode_cnn(&encode_cnn(&model, &[7; 16], &q)).unwrap();
        let back = cnn_from_trace(&trace).unwrap();
        assert_eq!(back.layers, model.layers);
        assert_eq!(back.name, "tiny");
        assert_eq!(input, vec![7; 16]);
    }

    #[test]
    fn qos_envelope_roundtrips_bit_exactly() {
        // Every (priority, deadline) shape survives the hop.
        for qos in [
            Qos::default(),
            Qos::best_effort(),
            Qos::default().with_deadline(Duration::from_micros(1)),
            Qos::best_effort().with_deadline(Duration::from_millis(50)),
            Qos::default().with_deadline(Duration::from_secs(3600)),
        ] {
            let (row, back) = decode_mlp(&encode_mlp(&[1, 2], &qos)).unwrap();
            assert_eq!(row, vec![1, 2]);
            assert_eq!(back, qos, "qos {qos:?} must round-trip");
        }
        // A sub-microsecond deadline clamps to 1 µs — it must not decode
        // as "no deadline" and wait forever.
        let tight = Qos::default().with_deadline(Duration::from_nanos(3));
        let (_, back) = decode_mlp(&encode_mlp(&[0], &tight)).unwrap();
        assert_eq!(back.deadline, Some(Duration::from_micros(1)));
        // An unknown priority byte is a corrupt frame, not a silent default.
        let mut w = PayloadWriter::new();
        w.put_vec_i32(&[1]);
        w.put_u8(7);
        w.put_u64(0);
        let err = decode_mlp(&w.finish()).unwrap_err();
        assert!(matches!(err, Error::Remote { kind: RemoteErrorKind::FrameCorrupt, .. }));
    }

    #[test]
    fn reply_roundtrips_with_report_and_layers() {
        let reply = Reply {
            outputs: vec![1, 2, 3],
            report: Some(ExecReport {
                sim_latency_s: 1.5e-6,
                energy_j: 2.5e-9,
                lanes: 10,
                noise_events: 3,
                row_noise: vec![1, 0, 2],
            }),
            layers: vec![LayerReport {
                layer: "conv1".into(),
                report: ExecReport { lanes: 4, ..Default::default() },
            }],
        };
        let back = decode_reply(&encode_reply(&Ok(reply.clone()))).unwrap().unwrap();
        assert_eq!(back.outputs, reply.outputs);
        assert_eq!(back.report, reply.report);
        assert_eq!(back.layers.len(), 1);
        assert_eq!(back.layers[0].layer, "conv1");
        assert_eq!(back.layers[0].report, reply.layers[0].report);
    }

    #[test]
    fn error_variants_survive_the_hop() {
        for e in [
            Error::ShardDown("pool died".into()),
            Error::Coordinator("bad request".into()),
            Error::Shape("8x8 vs 4x4".into()),
            Error::Remote { kind: RemoteErrorKind::PeerGone, detail: "downstream".into() },
            // QoS refusals keep their type across the wire (busy-not-dead
            // routing depends on it).
            Error::Overloaded("ingress queue full (8 slots)".into()),
            Error::DeadlineExceeded("queued 12.3 ms, deadline 10.0 ms".into()),
        ] {
            let text = e.to_string();
            let back = decode_reply(&encode_reply(&Err(e))).unwrap().unwrap_err();
            assert_eq!(back.to_string(), text);
        }
        // Io flattens to Runtime (io::Error cannot round-trip).
        let io = Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "disk"));
        let back = decode_reply(&encode_reply(&Err(io))).unwrap().unwrap_err();
        assert!(matches!(back, Error::Runtime(_)));
    }

    #[test]
    fn stats_snapshot_roundtrips() {
        let t = ShardTelemetry {
            label: "shard0:software".into(),
            requests: 100,
            completed: 95,
            failed: 5,
            batches: 12,
            cnn_frames: 7,
            cnn_batches: 3,
            sim_reports: 50,
            sim_latency_s: 0.25,
            energy_j: 1e-3,
            lanes: 4096,
            noise_events: 17,
            live_workers: 2,
            revivals: 1,
            shed: 23,
            shed_best_effort: 19,
            deadline_expired: 4,
        };
        let back = decode_stats(&encode_stats(&t)).unwrap();
        assert_eq!(back.label, t.label);
        assert_eq!(
            (back.requests, back.completed, back.failed, back.live_workers, back.revivals),
            (100, 95, 5, 2, 1)
        );
        assert_eq!(
            (back.shed, back.shed_best_effort, back.deadline_expired),
            (23, 19, 4),
            "v2 QoS counters must round-trip"
        );
        assert_eq!(back.sim_latency_s, 0.25);
    }
}
