//! Cross-host serving: wire protocol, shard server, and remote-shard client.
//!
//! The fleet router ([`crate::coordinator::FleetHandle`]) scales past one
//! process by mixing *remote* shards into its slot table: a [`ShardServer`]
//! fronts a local [`crate::coordinator::Coordinator`] (or a whole fleet) on
//! a TCP socket, and a [`RemoteShard`] client presents the same
//! submit / try_submit / ping / stats surface as a local shard, so routing
//! policies, retained-payload failover, and telemetry rollup apply
//! unchanged. Everything runs on std `TcpListener`/`TcpStream` — the crate
//! keeps its zero-dependency discipline.
//!
//! Robustness contract (the reason this module exists):
//!
//! * every connect/read/write carries an explicit deadline ([`NetConfig`]);
//! * reconnects use bounded exponential backoff with deterministic jitter;
//! * failures are typed ([`crate::error::RemoteErrorKind`]) and only the
//!   *truly unreachable* kinds (`ConnRefused`, `PeerGone`) map onto the
//!   fleet's [`crate::Error::ShardDown`] failover signal — one corrupt
//!   frame or one slow reply never retires a healthy shard;
//! * heartbeat pings (missed-pong threshold) retire an unresponsive shard,
//!   and the fleet janitor revives it by reconnecting.

pub mod client;
pub mod server;
pub mod wire;

pub use client::RemoteShard;
pub use server::{ServeTarget, ShardServer};
pub use wire::{Frame, Opcode, VERSION};

use std::io::Read;
use std::net::TcpStream;
use std::time::Duration;

use crate::testing::SplitMix64;

/// Deadlines and limits for every remote call. `Default` is tuned for
/// LAN-scale serving; tests shrink the timeouts to keep chaos runs fast.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Deadline for establishing a TCP connection.
    pub connect_timeout: Duration,
    /// Deadline for one request/reply exchange (also the socket write
    /// timeout). A reply slower than this resolves as request-level
    /// `Remote { Timeout }` — it does not retire the shard.
    pub io_timeout: Duration,
    /// Upper bound on a peer-declared payload length; larger frames are
    /// rejected as corrupt before any allocation.
    pub max_frame_len: usize,
    /// Reconnect attempts per [`RemoteShard::reconnect`] call.
    pub reconnect_attempts: u32,
    /// First reconnect backoff; doubles per attempt (with jitter).
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
    /// Heartbeat ping cadence; `Duration::ZERO` disables the heartbeat
    /// thread (health is then driven by per-request errors only).
    pub heartbeat_interval: Duration,
    /// Consecutive missed pongs that retire the shard (`PeerGone`).
    pub missed_pong_threshold: u32,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            connect_timeout: Duration::from_secs(2),
            io_timeout: Duration::from_secs(5),
            max_frame_len: 64 << 20,
            reconnect_attempts: 5,
            backoff_base: Duration::from_millis(50),
            backoff_max: Duration::from_secs(2),
            heartbeat_interval: Duration::ZERO,
            missed_pong_threshold: 3,
        }
    }
}

impl NetConfig {
    /// Builder: request/reply deadline.
    pub fn with_io_timeout(mut self, t: Duration) -> Self {
        self.io_timeout = t;
        self
    }

    /// Builder: connect deadline.
    pub fn with_connect_timeout(mut self, t: Duration) -> Self {
        self.connect_timeout = t;
        self
    }

    /// Builder: enable the heartbeat at `interval` with the given
    /// missed-pong threshold.
    pub fn with_heartbeat(mut self, interval: Duration, missed_pong_threshold: u32) -> Self {
        self.heartbeat_interval = interval;
        self.missed_pong_threshold = missed_pong_threshold.max(1);
        self
    }

    /// Builder: reconnect budget (attempts, first backoff, ceiling).
    pub fn with_backoff(mut self, attempts: u32, base: Duration, max: Duration) -> Self {
        self.reconnect_attempts = attempts.max(1);
        self.backoff_base = base;
        self.backoff_max = max;
        self
    }

    /// Jittered exponential backoff delay before reconnect `attempt`
    /// (0-based): `base · 2^attempt`, capped at `backoff_max`, scaled into
    /// `[0.5, 1.0)` by a deterministic per-peer jitter stream so a fleet of
    /// clients reconnecting to the same reborn server does not stampede in
    /// lockstep.
    pub fn backoff_delay(&self, attempt: u32, jitter_seed: u64) -> Duration {
        let exp = self
            .backoff_base
            .saturating_mul(1u32 << attempt.min(16))
            .min(self.backoff_max);
        let mut rng = SplitMix64::new(jitter_seed ^ (attempt as u64).wrapping_mul(0x9E37));
        let frac = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64; // [0,1)
        exp.mul_f64(0.5 + 0.5 * frac)
    }
}

/// How often blocked socket reads wake up to run housekeeping (deadline
/// expiry, stop-flag checks). This is the granularity of stall detection,
/// not a request deadline.
pub(crate) const POLL_SLICE: Duration = Duration::from_millis(25);

/// Configure a freshly connected stream for framed serving: no Nagle
/// batching on small frames, sliced read timeout (see [`POLL_SLICE`]), and
/// the config's write deadline.
pub(crate) fn configure_stream(s: &TcpStream, cfg: &NetConfig) -> std::io::Result<()> {
    s.set_nodelay(true)?;
    s.set_read_timeout(Some(POLL_SLICE))?;
    s.set_write_timeout(Some(cfg.io_timeout))?;
    Ok(())
}

/// `Read` adapter over a poll-timeout socket: transparently retries
/// `WouldBlock`/`TimedOut` reads, invoking `keep_going` on each idle slice.
/// Returning `false` from the callback aborts the read with `TimedOut`
/// (surfaced by [`wire::read_frame`] as `Remote { Timeout }`). A single
/// `read` consumes nothing when it times out, so retrying here keeps
/// `read_exact` framing intact — the stream never desynchronizes across
/// idle slices.
pub(crate) struct PollRead<'a, F: FnMut() -> bool> {
    pub stream: &'a TcpStream,
    pub keep_going: F,
}

impl<F: FnMut() -> bool> Read for PollRead<'_, F> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            match self.stream.read(buf) {
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if !(self.keep_going)() {
                        return Err(std::io::Error::new(
                            std::io::ErrorKind::TimedOut,
                            "read abandoned (stop or deadline)",
                        ));
                    }
                }
                // Retry EINTR like WouldBlock: nothing was consumed.
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                r => return r,
            }
        }
    }
}

/// Sleep `total` in [`POLL_SLICE`] slices, returning early (false) when
/// `stop` reports true. Returns true when the full duration elapsed.
pub(crate) fn sleep_sliced(total: Duration, mut stop: impl FnMut() -> bool) -> bool {
    let mut left = total;
    while left > Duration::ZERO {
        if stop() {
            return false;
        }
        let step = left.min(POLL_SLICE);
        std::thread::sleep(step);
        left = left.saturating_sub(step);
    }
    !stop()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_and_caps() {
        let cfg = NetConfig::default().with_backoff(
            8,
            Duration::from_millis(10),
            Duration::from_millis(500),
        );
        let d0 = cfg.backoff_delay(0, 1);
        let d3 = cfg.backoff_delay(3, 1);
        let d12 = cfg.backoff_delay(12, 1);
        assert!(d0 >= Duration::from_millis(5) && d0 < Duration::from_millis(10));
        assert!(d3 > d0, "backoff must grow: {d0:?} vs {d3:?}");
        assert!(d12 <= Duration::from_millis(500), "capped at backoff_max");
    }

    #[test]
    fn backoff_jitter_is_deterministic_per_seed() {
        let cfg = NetConfig::default();
        assert_eq!(cfg.backoff_delay(2, 77), cfg.backoff_delay(2, 77));
        assert_ne!(cfg.backoff_delay(2, 77), cfg.backoff_delay(2, 78));
    }

    #[test]
    fn sliced_sleep_stops_early() {
        let t0 = std::time::Instant::now();
        let done = sleep_sliced(Duration::from_secs(30), || true);
        assert!(!done);
        assert!(t0.elapsed() < Duration::from_secs(1));
    }
}
