//! `RemoteShard`: the client half of cross-host serving.
//!
//! A `RemoteShard` speaks the [`super::wire`] protocol to one
//! [`super::ShardServer`] and presents the *same* surface as a local shard
//! (`try_submit_*` returning payload-recovering [`Rejected`], `ping`,
//! `stats`), so the fleet router can hold local and remote shards in one
//! slot table. One connection carries any number of in-flight requests,
//! correlated by `request_id`:
//!
//! * submits register a bounded(1) response slot plus a deadline in the
//!   pending map, then write the frame;
//! * a dedicated reader thread decodes replies and fulfils the slots;
//!   between frames it expires overdue entries with `Remote { Timeout }` —
//!   a stalled peer trips `io_timeout`, it never hangs a caller;
//! * connection death (EOF / reset / killed process) fails every pending
//!   entry with `Remote { PeerGone }`, which the router maps to shard-down
//!   so retained-payload retry resubmits on a survivor;
//! * a corrupt or version-skewed frame fails pending entries with its
//!   *request-level* kind and reconnects in place — the shard is not
//!   retired (same poison-payload discipline as local shards);
//! * an optional heartbeat thread pings on a cadence; crossing the
//!   missed-pong threshold retires the shard until a revival reconnects.
//!
//! Client-side [`CoordinatorStats`] mirror what *this client* routed to the
//! peer (requests / completed / failed / latency), which keeps queue-depth
//! routing and fleet telemetry local and cheap; `live_workers` doubles as a
//! 0/1 reachability gauge. [`RemoteShard::fetch_stats`] does a synchronous
//! Stats RPC when the server's own counters are wanted.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering::Relaxed};
use std::sync::mpsc::{sync_channel, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::request::{response_slot, ResponseTx};
use crate::coordinator::{CoordinatorStats, Qos, Rejected, Reply, Response};
use crate::dnn::models::CnnModel;
use crate::error::RemoteErrorKind;
use crate::metrics::ShardTelemetry;
use crate::net::wire::{self, classify_io, remote_err, Frame, Opcode};
use crate::net::{configure_stream, sleep_sliced, NetConfig, PollRead};
use crate::sync::lock_recovered;
use crate::{Error, Result};

/// One in-flight request awaiting its reply frame.
struct Pending {
    reply: ResponseTx,
    deadline: Instant,
    enqueued: Instant,
    /// Pings/pongs stay out of the request/completed/failed counters,
    /// mirroring the local shard contract (probing never skews routing).
    counts: bool,
}

/// An established connection: the writer half plus its reader thread.
struct Conn {
    writer: TcpStream,
    generation: u64,
    reader: Option<JoinHandle<()>>,
}

/// Lock policy (see [`crate::sync`]): the fallible serving paths that
/// acquire `conn` (`establish`, `write_frame_or_fail`) map a poisoned lock
/// to a typed `Error::Remote { kind: PeerGone }` via [`Self::conn_poisoned`]
/// — connection state touched by a panicking thread is unknowable, and
/// `PeerGone` routes the shard through the same teardown/revival machinery
/// as a dead peer. Every other guarded structure (`pending`,
/// `pending_stats`, `retired_readers`, the heartbeat handle) is a plain
/// collection that is valid in every state and is touched by must-complete
/// paths (dispatch, expiry, teardown), so those recover the guard with
/// [`lock_recovered`] — a panicking reader thread can never cascade-panic
/// the client.
struct RemoteInner {
    addr: SocketAddr,
    label: String,
    cfg: NetConfig,
    stats: Arc<CoordinatorStats>,
    conn: Mutex<Option<Conn>>,
    pending: Mutex<HashMap<u64, Pending>>,
    pending_stats: Mutex<HashMap<u64, SyncSender<ShardTelemetry>>>,
    next_id: AtomicU64,
    generations: AtomicU64,
    missed_pongs: AtomicU32,
    stop: AtomicBool,
    /// Reader threads of torn-down generations, joined at disconnect so no
    /// polling thread outlives the shard (same join discipline as the
    /// fleet's janitor).
    retired_readers: Mutex<Vec<JoinHandle<()>>>,
}

/// Client handle to one remote shard server. Unique owner of its reader and
/// heartbeat threads: dropping (or [`RemoteShard::disconnect`]) stops and
/// joins them.
pub struct RemoteShard {
    inner: Arc<RemoteInner>,
    heartbeat: Mutex<Option<JoinHandle<()>>>,
}

impl std::fmt::Debug for RemoteShard {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RemoteShard")
            .field("addr", &self.inner.addr)
            .field("label", &self.inner.label)
            .field("reachable", &self.is_reachable())
            .finish()
    }
}

impl RemoteShard {
    /// Connect to a shard server, respecting `cfg.connect_timeout`. The
    /// label is used in telemetry rollups (e.g. `remote0@127.0.0.1:7401`).
    pub fn connect(addr: &str, label: impl Into<String>, cfg: NetConfig) -> Result<RemoteShard> {
        let sockaddr = addr
            .to_socket_addrs()
            .map_err(|e| Error::Config(format!("bad remote address {addr:?}: {e}")))?
            .next()
            .ok_or_else(|| Error::Config(format!("remote address {addr:?} resolves to nothing")))?;
        let inner = Arc::new(RemoteInner {
            addr: sockaddr,
            label: label.into(),
            cfg,
            stats: Arc::new(CoordinatorStats::default()),
            conn: Mutex::new(None),
            pending: Mutex::new(HashMap::new()),
            pending_stats: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            generations: AtomicU64::new(0),
            missed_pongs: AtomicU32::new(0),
            stop: AtomicBool::new(false),
            retired_readers: Mutex::new(Vec::new()),
        });
        inner.establish()?;
        let heartbeat = if inner.cfg.heartbeat_interval > Duration::ZERO {
            let hb = inner.clone();
            Some(
                std::thread::Builder::new()
                    .name(format!("remote-heartbeat-{}", inner.label))
                    .spawn(move || hb.heartbeat_loop())
                    .map_err(|e| Error::Runtime(format!("spawn heartbeat: {e}")))?,
            )
        } else {
            None
        };
        Ok(RemoteShard { inner, heartbeat: Mutex::new(heartbeat) })
    }

    /// Telemetry label.
    pub fn label(&self) -> &str {
        &self.inner.label
    }

    /// Resolved peer address.
    pub fn addr(&self) -> SocketAddr {
        self.inner.addr
    }

    /// Client-side serving stats (what this client routed to the peer).
    pub fn stats(&self) -> &CoordinatorStats {
        &self.inner.stats
    }

    /// The client-side stats behind their `Arc`.
    pub fn stats_arc(&self) -> Arc<CoordinatorStats> {
        self.inner.stats.clone()
    }

    /// Whether a connection is currently established (the 0/1 gauge behind
    /// `stats().live_workers`).
    pub fn is_reachable(&self) -> bool {
        self.inner.stats.live_workers.load(Relaxed) > 0
    }

    /// Payload-recovering GEMM submission over the wire (see the local
    /// [`crate::coordinator::CoordinatorHandle::try_submit_gemm`] contract).
    pub fn try_submit_gemm(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<(Vec<i32>, Vec<i32>)>> {
        self.try_submit_gemm_qos(artifact, a, b, Qos::default())
    }

    /// [`RemoteShard::try_submit_gemm`] with an explicit QoS envelope. The
    /// envelope crosses the wire (v2 submit payloads) and the server
    /// re-anchors the deadline at its own enqueue instant; a server-side
    /// shed comes back typed [`Error::Overloaded`] through the reply slot.
    pub fn try_submit_gemm_qos(
        &self,
        artifact: &str,
        a: Vec<i32>,
        b: Vec<i32>,
        qos: Qos,
    ) -> std::result::Result<Response, Rejected<(Vec<i32>, Vec<i32>)>> {
        let payload = wire::encode_gemm(artifact, &a, &b, &qos);
        match self.inner.send_submit(Opcode::SubmitGemm, payload) {
            Ok(rx) => Ok(rx),
            Err(error) => Err(Rejected { error, payload: (a, b) }),
        }
    }

    /// Payload-recovering MLP submission over the wire.
    pub fn try_submit_mlp(
        &self,
        row: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<Vec<i32>>> {
        self.try_submit_mlp_qos(row, Qos::default())
    }

    /// [`RemoteShard::try_submit_mlp`] with an explicit QoS envelope (see
    /// [`RemoteShard::try_submit_gemm_qos`]).
    pub fn try_submit_mlp_qos(
        &self,
        row: Vec<i32>,
        qos: Qos,
    ) -> std::result::Result<Response, Rejected<Vec<i32>>> {
        let payload = wire::encode_mlp(&row, &qos);
        match self.inner.send_submit(Opcode::SubmitMlp, payload) {
            Ok(rx) => Ok(rx),
            Err(error) => Err(Rejected { error, payload: row }),
        }
    }

    /// Payload-recovering CNN submission over the wire (the model ships as
    /// trace text; see [`wire::encode_cnn`]).
    pub fn try_submit_cnn(
        &self,
        model: CnnModel,
        input: Vec<i32>,
    ) -> std::result::Result<Response, Rejected<(CnnModel, Vec<i32>)>> {
        self.try_submit_cnn_qos(model, input, Qos::default())
    }

    /// [`RemoteShard::try_submit_cnn`] with an explicit QoS envelope (see
    /// [`RemoteShard::try_submit_gemm_qos`]).
    pub fn try_submit_cnn_qos(
        &self,
        model: CnnModel,
        input: Vec<i32>,
        qos: Qos,
    ) -> std::result::Result<Response, Rejected<(CnnModel, Vec<i32>)>> {
        let payload = wire::encode_cnn(&model, &input, &qos);
        match self.inner.send_submit(Opcode::SubmitCnn, payload) {
            Ok(rx) => Ok(rx),
            Err(error) => Err(Rejected { error, payload: (model, input) }),
        }
    }

    /// End-to-end health probe: a Ping frame the server routes through its
    /// worker pool. `Ok` proves the peer serves; pings stay out of the
    /// request counters on both sides.
    pub fn ping(&self, timeout: Duration) -> Result<()> {
        let (reply, rx) = response_slot();
        let id = self.inner.register(reply, timeout, false);
        self.inner.write_frame_or_fail(Frame::control(Opcode::Ping, id), false)?;
        match rx.recv_timeout(timeout) {
            Ok(Ok(_)) => Ok(()),
            Ok(Err(e)) => Err(e),
            Err(RecvTimeoutError::Timeout) => {
                lock_recovered(&self.inner.pending).remove(&id);
                Err(remote_err(
                    RemoteErrorKind::Timeout,
                    format!("{}: ping got no pong within {timeout:?}", self.inner.label),
                ))
            }
            Err(RecvTimeoutError::Disconnected) => Err(remote_err(
                RemoteErrorKind::PeerGone,
                format!("{}: connection dropped during ping", self.inner.label),
            )),
        }
    }

    /// Synchronous Stats RPC: the server's own [`ShardTelemetry`] snapshot
    /// (its counters, not this client's mirror).
    pub fn fetch_stats(&self, timeout: Duration) -> Result<ShardTelemetry> {
        let (tx, rx) = sync_channel(1);
        let id = self.inner.next_id.fetch_add(1, Relaxed);
        lock_recovered(&self.inner.pending_stats).insert(id, tx);
        if let Err(e) = self.inner.write_frame_or_fail(Frame::control(Opcode::Stats, id), false) {
            lock_recovered(&self.inner.pending_stats).remove(&id);
            return Err(e);
        }
        rx.recv_timeout(timeout).map_err(|_| {
            lock_recovered(&self.inner.pending_stats).remove(&id);
            remote_err(
                RemoteErrorKind::Timeout,
                format!("{}: no stats reply within {timeout:?}", self.inner.label),
            )
        })
    }

    /// Ask the peer process to leave its serve loop (CI / orderly teardown).
    /// Best-effort: a dead peer is already what shutdown wanted.
    pub fn request_server_shutdown(&self) -> Result<()> {
        self.inner.write_frame_or_fail(Frame::control(Opcode::Shutdown, 0), false)
    }

    /// Tear down and re-establish the connection with bounded, jittered
    /// exponential backoff ([`NetConfig::backoff_delay`]). This is the
    /// revival path: the fleet janitor calls it (via the router) when the
    /// heartbeat or a peer-gone error retired this shard.
    pub fn reconnect(&self) -> Result<()> {
        self.inner.reconnect()
    }

    /// Stop the heartbeat, fail pending requests, close the connection, and
    /// join every thread this shard spawned (the same join-on-shutdown
    /// discipline as the fleet janitor — nothing is left polling).
    pub fn disconnect(&self) {
        self.inner.stop.store(true, Relaxed);
        self.inner.teardown(None, RemoteErrorKind::PeerGone, "client disconnecting");
        let hb = lock_recovered(&self.heartbeat).take();
        if let Some(h) = hb {
            let _ = h.join();
        }
        let retired: Vec<_> = lock_recovered(&self.inner.retired_readers).drain(..).collect();
        for h in retired {
            let _ = h.join();
        }
    }
}

impl Drop for RemoteShard {
    fn drop(&mut self) {
        self.disconnect();
    }
}

impl RemoteInner {
    /// Typed error for a poisoned `conn` lock on a fallible serving path
    /// (see the struct-level lock policy).
    fn conn_poisoned(&self) -> Error {
        remote_err(
            RemoteErrorKind::PeerGone,
            format!("{}: connection state poisoned by a panicked client thread", self.label),
        )
    }

    /// Open a configured stream to the peer.
    fn dial(&self) -> Result<TcpStream> {
        let s = TcpStream::connect_timeout(&self.addr, self.cfg.connect_timeout)
            .map_err(|e| classify_io(&e, &format!("connect {}", self.addr)))?;
        configure_stream(&s, &self.cfg)
            .map_err(|e| classify_io(&e, &format!("configure {}", self.addr)))?;
        Ok(s)
    }

    /// Install a fresh connection (dial + spawn reader); marks reachable.
    fn establish(self: &Arc<Self>) -> Result<()> {
        let stream = self.dial()?;
        let generation = self.generations.fetch_add(1, Relaxed) + 1;
        let reader_stream = stream
            .try_clone()
            .map_err(|e| classify_io(&e, "clone stream for reader"))?;
        let me = self.clone();
        let reader = std::thread::Builder::new()
            .name(format!("remote-reader-{}", self.label))
            .spawn(move || me.reader_loop(reader_stream, generation))
            .map_err(|e| Error::Runtime(format!("spawn reader: {e}")))?;
        let mut conn = self.conn.lock().map_err(|_| self.conn_poisoned())?;
        if let Some(old) = conn.take() {
            let _ = old.writer.shutdown(std::net::Shutdown::Both);
            if let Some(h) = old.reader {
                lock_recovered(&self.retired_readers).push(h);
            }
        }
        *conn = Some(Conn { writer: stream, generation, reader: Some(reader) });
        drop(conn);
        self.stats.live_workers.store(1, Relaxed);
        self.missed_pongs.store(0, Relaxed);
        Ok(())
    }

    /// Bounded backoff reconnect (see [`RemoteShard::reconnect`]).
    fn reconnect(self: &Arc<Self>) -> Result<()> {
        if self.stop.load(Relaxed) {
            return Err(remote_err(RemoteErrorKind::PeerGone, "shard is shut down"));
        }
        let seed = wire::fnv1a(wire::FNV_OFFSET, self.label.as_bytes())
            ^ wire::fnv1a(wire::FNV_OFFSET, format!("{}", self.addr).as_bytes());
        let mut last = None;
        for attempt in 0..self.cfg.reconnect_attempts.max(1) {
            if attempt > 0 {
                let delay = self.cfg.backoff_delay(attempt - 1, seed);
                if !sleep_sliced(delay, || self.stop.load(Relaxed)) {
                    return Err(remote_err(RemoteErrorKind::PeerGone, "shard is shut down"));
                }
            }
            match self.establish() {
                Ok(()) => return Ok(()),
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap_or_else(|| {
            remote_err(RemoteErrorKind::ConnRefused, format!("{}: reconnect failed", self.label))
        }))
    }

    /// Register a pending entry; returns its request id.
    fn register(&self, reply: ResponseTx, deadline: Duration, counts: bool) -> u64 {
        let id = self.next_id.fetch_add(1, Relaxed);
        let now = Instant::now();
        lock_recovered(&self.pending).insert(
            id,
            Pending { reply, deadline: now + deadline, enqueued: now, counts },
        );
        id
    }

    /// Write a frame on the current connection. On failure the connection
    /// is torn down (pending entries fail with the classified kind) and the
    /// typed error is returned. `counted` says whether the caller already
    /// bumped `stats.requests` for this frame (so the mirror stays exact —
    /// same discipline as the local `send_job`).
    fn write_frame_or_fail(&self, frame: Frame, counted: bool) -> Result<()> {
        let mut conn = match self.conn.lock() {
            Ok(guard) => guard,
            Err(_) => {
                if counted {
                    self.stats.requests.fetch_sub(1, Relaxed);
                }
                return Err(self.conn_poisoned());
            }
        };
        let state = match conn.as_mut() {
            Some(s) => s,
            None => {
                if counted {
                    self.stats.requests.fetch_sub(1, Relaxed);
                }
                return Err(remote_err(
                    RemoteErrorKind::PeerGone,
                    format!("{}: not connected (awaiting revival)", self.label),
                ));
            }
        };
        match wire::write_frame(&mut state.writer, &frame) {
            Ok(()) => Ok(()),
            Err(e) => {
                if counted {
                    self.stats.requests.fetch_sub(1, Relaxed);
                }
                let generation = state.generation;
                drop(conn);
                let kind = match &e {
                    Error::Remote { kind, .. } => *kind,
                    _ => RemoteErrorKind::PeerGone,
                };
                self.teardown(Some(generation), kind, "write failed");
                Err(e)
            }
        }
    }

    /// Submit path shared by gemm/mlp/cnn: register slot, count, write.
    fn send_submit(&self, opcode: Opcode, payload: Vec<u8>) -> Result<Response> {
        let (reply, rx) = response_slot();
        let id = self.register(reply, self.cfg.io_timeout, true);
        self.stats.requests.fetch_add(1, Relaxed);
        match self.write_frame_or_fail(Frame { opcode, request_id: id, payload }, true) {
            Ok(()) => Ok(rx),
            Err(e) => {
                lock_recovered(&self.pending).remove(&id);
                Err(e)
            }
        }
    }

    /// Fail every pending entry with a fresh `Remote { kind }` error and
    /// drop the connection state of `generation` (or any, when `None`).
    /// Reachability goes to 0 only for shard-retiring kinds, so a corrupt
    /// frame resets the connection without retiring the shard.
    fn teardown(&self, generation: Option<u64>, kind: RemoteErrorKind, why: &str) {
        {
            // Teardown must complete even after a panic elsewhere — recover
            // rather than error: this *is* the cleanup the typed-error
            // callers rely on.
            let mut conn = lock_recovered(&self.conn);
            let matches_gen =
                conn.as_ref().map(|c| generation.map_or(true, |g| g == c.generation));
            if matches_gen == Some(true) {
                if let Some(old) = conn.take() {
                    let _ = old.writer.shutdown(std::net::Shutdown::Both);
                    if let Some(h) = old.reader {
                        lock_recovered(&self.retired_readers).push(h);
                    }
                }
            }
        }
        if kind.retires_shard() {
            self.stats.live_workers.store(0, Relaxed);
        }
        let drained: Vec<Pending> =
            lock_recovered(&self.pending).drain().map(|(_, p)| p).collect();
        for p in drained {
            if p.counts {
                self.stats.failed.fetch_add(1, Relaxed);
            }
            let _ = p.reply.send(Err(remote_err(
                kind,
                format!("{}: {why} with request in flight", self.label),
            )));
        }
        lock_recovered(&self.pending_stats).clear();
    }

    /// Expire overdue pending entries with `Remote { Timeout }` — the
    /// request-level deadline. Runs on the reader's idle slices, so a
    /// stalled peer (accept-then-silence) trips `io_timeout` instead of
    /// hanging callers, without retiring the shard.
    fn expire_overdue(&self) {
        let now = Instant::now();
        let mut pending = lock_recovered(&self.pending);
        let overdue: Vec<u64> = pending
            .iter()
            .filter(|(_, p)| now >= p.deadline)
            .map(|(id, _)| *id)
            .collect();
        for id in overdue {
            if let Some(p) = pending.remove(&id) {
                if p.counts {
                    self.stats.failed.fetch_add(1, Relaxed);
                }
                let _ = p.reply.send(Err(remote_err(
                    RemoteErrorKind::Timeout,
                    format!("{}: no reply within {:?}", self.label, self.cfg.io_timeout),
                )));
            }
        }
    }

    /// Whether `generation` is still the installed connection.
    fn is_current(&self, generation: u64) -> bool {
        // Reader-side liveness check: recover so readers of a poisoned
        // client still observe supersession and exit their loops.
        lock_recovered(&self.conn)
            .as_ref()
            .map(|c| c.generation == generation)
            .unwrap_or(false)
    }

    /// Per-connection reader: decode frames, fulfil pending slots, expire
    /// deadlines between frames, classify connection death.
    fn reader_loop(self: Arc<Self>, stream: TcpStream, generation: u64) {
        loop {
            let mut poll = PollRead {
                stream: &stream,
                keep_going: || {
                    self.expire_overdue();
                    !self.stop.load(Relaxed) && self.is_current(generation)
                },
            };
            match wire::read_frame(&mut poll, self.cfg.max_frame_len) {
                Ok(frame) => self.dispatch(frame),
                Err(Error::Remote { kind: RemoteErrorKind::Timeout, .. }) => {
                    // PollRead aborted: stopped or superseded. Exit quietly.
                    return;
                }
                Err(Error::Remote { kind, .. })
                    if matches!(
                        kind,
                        RemoteErrorKind::FrameCorrupt | RemoteErrorKind::VersionMismatch
                    ) =>
                {
                    // Request-level kinds: fail what was in flight with the
                    // typed error, then repair the stream in place. The
                    // shard is only retired if the repair itself fails.
                    self.teardown(Some(generation), kind, "stream desynchronized");
                    if !self.stop.load(Relaxed) {
                        if let Err(e) = self.reconnect() {
                            let k = match &e {
                                Error::Remote { kind, .. } => *kind,
                                _ => RemoteErrorKind::PeerGone,
                            };
                            self.teardown(None, k, "reconnect after corrupt frame failed");
                        }
                    }
                    return;
                }
                Err(_) => {
                    // EOF / reset / killed peer: the shard is unreachable.
                    self.teardown(Some(generation), RemoteErrorKind::PeerGone, "peer gone");
                    return;
                }
            }
        }
    }

    /// Route one inbound frame to its pending slot.
    fn dispatch(&self, frame: Frame) {
        match frame.opcode {
            Opcode::Reply => {
                let entry = lock_recovered(&self.pending).remove(&frame.request_id);
                let Some(p) = entry else { return }; // expired or stale
                let outcome = match wire::decode_reply(&frame.payload) {
                    Ok(o) => o,
                    Err(e) => Err(e),
                };
                if p.counts {
                    match &outcome {
                        Ok(_) => {
                            self.stats.completed.fetch_add(1, Relaxed);
                            self.stats.record_latency(p.enqueued.elapsed().as_secs_f64());
                        }
                        Err(_) => {
                            self.stats.failed.fetch_add(1, Relaxed);
                        }
                    }
                }
                let _ = p.reply.send(outcome);
            }
            Opcode::Pong => {
                self.missed_pongs.store(0, Relaxed);
                if let Some(p) = lock_recovered(&self.pending).remove(&frame.request_id) {
                    let _ = p.reply.send(Ok(Reply::bare(Vec::new())));
                }
            }
            Opcode::Stats => {
                if let Some(tx) = lock_recovered(&self.pending_stats).remove(&frame.request_id) {
                    if let Ok(t) = wire::decode_stats(&frame.payload) {
                        let _ = tx.send(t);
                    }
                }
            }
            // A server never sends submits/pings/shutdowns; ignore stale or
            // confused frames rather than killing a healthy connection.
            _ => {}
        }
    }

    /// Heartbeat: ping on a cadence; crossing the missed-pong threshold
    /// retires the shard (`PeerGone` → fleet failover) until a revival
    /// reconnects. Reconnection is deliberately *not* attempted here — the
    /// fleet janitor owns revival, so health marking and healing stay
    /// separate (and a stopped fleet cannot be resurrected by a stray
    /// heartbeat).
    fn heartbeat_loop(self: Arc<Self>) {
        loop {
            if !sleep_sliced(self.cfg.heartbeat_interval, || self.stop.load(Relaxed)) {
                return;
            }
            if lock_recovered(&self.conn).is_none() {
                continue; // down; revival is the janitor's job
            }
            let (reply, rx) = response_slot();
            let id = self.register(reply, self.cfg.io_timeout, false);
            let sent = self.write_frame_or_fail(Frame::control(Opcode::Ping, id), false);
            let ponged = sent.is_ok()
                && matches!(rx.recv_timeout(self.cfg.io_timeout), Ok(Ok(_)));
            if ponged {
                continue;
            }
            lock_recovered(&self.pending).remove(&id);
            let missed = self.missed_pongs.fetch_add(1, Relaxed) + 1;
            if missed >= self.cfg.missed_pong_threshold {
                self.teardown(
                    None,
                    RemoteErrorKind::PeerGone,
                    &format!("missed {missed} heartbeat pongs"),
                );
            }
        }
    }
}
