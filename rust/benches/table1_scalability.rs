//! Bench: regenerate paper **Table I** (+ Table II constants) and time the
//! link-budget solver.
//!
//! Run: `cargo bench --bench table1_scalability`

use spoga::benchkit::bench;
use spoga::devices::{Adc, Dac};
use spoga::optics::{paper_table1, solve_table1};
use spoga::report::Table;
use spoga::units::DataRate;

fn main() {
    // ---- Table II (input constants, printed for provenance) ---------------
    let mut t2 = Table::new(vec!["Converter", "BR (GS/s)", "Area (mm2)", "Power (mW)"]);
    for dr in DataRate::ALL {
        let a = Adc::for_rate(dr);
        t2.row(vec![
            "ADC".into(),
            dr.gs().to_string(),
            format!("{}", a.area_mm2),
            format!("{}", a.power_mw),
        ]);
    }
    for dr in DataRate::ALL {
        let d = Dac::for_rate(dr);
        t2.row(vec![
            "DAC".into(),
            dr.gs().to_string(),
            format!("{}", d.area_mm2),
            format!("{}", d.power_mw),
        ]);
    }
    println!("Table II — converter design points (paper values, pinned by tests):\n{}", t2.render());

    // ---- Table I ------------------------------------------------------------
    let solved = solve_table1();
    let paper = paper_table1();
    let mut t = Table::new(vec!["Architecture", "1 GS/s", "5 GS/s", "10 GS/s", "paper", "match"]);
    let mut all = true;
    for (s, p) in solved.rows.iter().zip(paper.rows.iter()) {
        let c = |nm: (usize, usize)| format!("{}x{}", nm.0, nm.1);
        let ok = s.nm == p.nm;
        all &= ok;
        t.row(vec![
            s.label.clone(),
            c(s.nm[0]),
            c(s.nm[1]),
            c(s.nm[2]),
            format!("{}/{}/{}", c(p.nm[0]), c(p.nm[1]), c(p.nm[2])),
            if ok { "yes".into() } else { "NO".into() },
        ]);
    }
    println!("Table I — scalability analysis:\n{}", t.render());
    assert!(all, "Table I mismatch — see rows above");
    println!("Table I reproduces the paper cell-for-cell.\n");

    // ---- solver timing --------------------------------------------------------
    let stats = bench(3, 100, solve_table1);
    println!("solver: {stats} ({:.0} tables/s)", stats.per_second());
}
