//! Bench: bit-sliced GEMM engine throughput — naive oracle vs packed
//! single-thread vs packed+threads vs prepacked serving rows, across
//! {64, 256, 1024}³ shapes.
//!
//! This is the recorded artifact for the packed-plane engine PR and the
//! pack-once/stream-many PR: effective GOPS (2·m·k·n ops per GEMM) for the
//! SPOGA three-lane dataflow, plus the packed-over-naive speedup. Two
//! measurement families:
//!
//! * `packed` / `packed_mt` pin the **scalar** micro-kernel and repack B
//!   every call — the historical rows, kept comparable across PRs;
//! * `packed_planned` / `packed_planned_simd` time the **serving** path:
//!   B is prepacked outside the timed loop (what a plan cache holds) and
//!   only the activation side packs per iteration — scalar vs the SIMD
//!   default micro-kernel.
//!
//! Results are printed as a table and written as JSON (default
//! `BENCH_bitslice.json`, override with the `BITSLICE_BENCH_OUT` env var)
//! so future perf PRs have a trajectory baseline.
//!
//! Run: `cargo bench --bench bitslice_throughput [max_dim]`
//! (`max_dim` defaults to 1024; pass 256 for a quick pass.)

use spoga::benchkit::bench;
use spoga::bitslice::kernel::default_threads;
use spoga::bitslice::{
    gemm_lanes_naive, gemm_lanes_packed, gemm_lanes_tiled, pack_b, MicroKernel, NibblePlanes,
    TileConfig,
};
use spoga::report::{fmt_ratio, fmt_sig, Table};
use spoga::testing::SplitMix64;

struct ShapeResult {
    dim: usize,
    naive_gops: f64,
    packed_gops: f64,
    packed_mt_gops: f64,
    packed_planned_gops: f64,
    packed_planned_simd_gops: f64,
}

fn gops(dim: usize, seconds: f64) -> f64 {
    2.0 * (dim as f64).powi(3) / seconds / 1e9
}

fn main() {
    let max_dim: usize = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let threads = default_threads();
    println!("bitslice GEMM throughput (SPOGA three-lane dataflow), {threads} threads available\n");

    // Smoke check before timing anything: the kernels must agree bit-exactly
    // across the repack path, the prepacked path, and both micro-kernels.
    {
        let mut rng = SplitMix64::new(4242);
        let a = rng.i8_vec(64 * 64);
        let b = rng.i8_vec(64 * 64);
        let oracle = gemm_lanes_naive(&a, &b, 64, 64, 64).unwrap();
        let pa = NibblePlanes::pack(&a, 64, 64).unwrap();
        let pb = pack_b(&b, 64, 64).unwrap();
        for micro in [MicroKernel::Scalar, MicroKernel::Simd] {
            let cfg = TileConfig::auto().with_micro(micro);
            let fast = gemm_lanes_tiled(&a, &b, 64, 64, 64, &cfg).unwrap();
            let planned = gemm_lanes_packed(&pa, pb.planes(), &cfg).unwrap();
            assert_eq!(oracle.hi, fast.hi);
            assert_eq!(oracle.mid, fast.mid);
            assert_eq!(oracle.lo, fast.lo);
            assert_eq!(oracle.hi, planned.hi);
            assert_eq!(oracle.mid, planned.mid);
            assert_eq!(oracle.lo, planned.lo);
        }
    }

    let mut results = Vec::new();
    let mut t = Table::new(vec![
        "shape",
        "naive (GOPS)",
        "packed 1T (GOPS)",
        "packed MT (GOPS)",
        "planned MT (GOPS)",
        "planned SIMD (GOPS)",
        "MT vs naive",
    ]);

    for dim in [64usize, 256, 1024] {
        if dim > max_dim {
            println!("(skipping {dim}^3: max_dim {max_dim})");
            continue;
        }
        let mut rng = SplitMix64::new(dim as u64);
        let a = rng.i8_vec(dim * dim);
        let b = rng.i8_vec(dim * dim);

        // Iteration budget ~2e8 MACs per timed kernel, at least one run.
        let iters = (200_000_000 / (dim * dim * dim)).clamp(1, 50);
        let warmup = usize::from(dim < 1024);

        let naive = bench(warmup, iters, || {
            gemm_lanes_naive(&a, &b, dim, dim, dim).unwrap()
        });
        // Historical rows: scalar micro-kernel, repack-per-call — directly
        // comparable with snapshots recorded before the SIMD/prepacked PR.
        let single = TileConfig::single_thread().with_micro(MicroKernel::Scalar);
        let packed = bench(warmup, iters, || {
            gemm_lanes_tiled(&a, &b, dim, dim, dim, &single).unwrap()
        });
        let multi = TileConfig::auto().with_micro(MicroKernel::Scalar);
        let packed_mt = bench(warmup, iters, || {
            gemm_lanes_tiled(&a, &b, dim, dim, dim, &multi).unwrap()
        });

        // Serving rows: B prepacked once outside the timer (the plan-cache
        // state), activation planes packed per iteration into a reused
        // scratch — exactly the backend hot path's work per request.
        let pb = pack_b(&b, dim, dim).unwrap();
        let mut planes = NibblePlanes::default();
        let simd = TileConfig::auto();
        let planned = bench(warmup, iters, || {
            planes.pack_into(&a, dim, dim).unwrap();
            gemm_lanes_packed(&planes, pb.planes(), &multi).unwrap()
        });
        let planned_simd = bench(warmup, iters, || {
            planes.pack_into(&a, dim, dim).unwrap();
            gemm_lanes_packed(&planes, pb.planes(), &simd).unwrap()
        });

        let r = ShapeResult {
            dim,
            naive_gops: gops(dim, naive.min_s),
            packed_gops: gops(dim, packed.min_s),
            packed_mt_gops: gops(dim, packed_mt.min_s),
            packed_planned_gops: gops(dim, planned.min_s),
            packed_planned_simd_gops: gops(dim, planned_simd.min_s),
        };
        t.row(vec![
            format!("{dim}x{dim}x{dim}"),
            fmt_sig(r.naive_gops, 3),
            fmt_sig(r.packed_gops, 3),
            fmt_sig(r.packed_mt_gops, 3),
            fmt_sig(r.packed_planned_gops, 3),
            fmt_sig(r.packed_planned_simd_gops, 3),
            fmt_ratio(r.packed_mt_gops / r.naive_gops),
        ]);
        results.push(r);
    }

    println!("{}", t.render());
    if let Some(r) = results.iter().find(|r| r.dim == 256) {
        println!(
            "acceptance gates (256^3): packed+threads vs naive {:.2}x; \
             planned SIMD vs planned scalar {:.2}x",
            r.packed_mt_gops / r.naive_gops,
            r.packed_planned_simd_gops / r.packed_planned_gops
        );
    }

    // JSON snapshot for the perf trajectory.
    let out_path = std::env::var("BITSLICE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_bitslice.json".to_string());
    let shapes: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "    {{\"dim\": {}, \"naive_gops\": {:.4}, \"packed_gops\": {:.4}, \
                 \"packed_mt_gops\": {:.4}, \"packed_planned_gops\": {:.4}, \
                 \"packed_planned_simd_gops\": {:.4}, \"speedup_mt_vs_naive\": {:.3}}}",
                r.dim,
                r.naive_gops,
                r.packed_gops,
                r.packed_mt_gops,
                r.packed_planned_gops,
                r.packed_planned_simd_gops,
                r.packed_mt_gops / r.naive_gops
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"bitslice_throughput\",\n  \"dataflow\": \"spoga_three_lane\",\n  \
         \"ops_definition\": \"2*m*k*n per GEMM, best-of-n timing\",\n  \
         \"status\": \"measured\",\n  \
         \"threads_available\": {},\n  \"results\": [\n{}\n  ]\n}}\n",
        threads,
        shapes.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}
