//! Bench: resilience-layer costs — mid-flight failover throughput and
//! shard recovery time.
//!
//! Scenario `baseline`: a mixed retrying-slot burst against a healthy
//! 2-shard software fleet. Scenario `mid_flight_failover`: the same burst,
//! but shard 0's worker pool is killed while its batching window still
//! holds accepted jobs — every slot must resolve on the survivor, and the
//! gap between the two `req_per_s` figures is the failover tax. Scenario
//! `revival` measures wall-clock from `revive_shard` to a serving pool
//! (worker respawn + engine warmup + health probe). Scenarios
//! `overload_high` / `overload_best_effort` drive blocking per-request
//! clients against a watermarked 1-shard fleet: High is never shed (its
//! `p99_us` is the held latency), BestEffort absorbs typed admission
//! sheds — the `shed` column counts them, and its `p99_us` covers the
//! requests that were served.
//!
//! Self-contained (synthetic manifest in a temp dir). Results print as a
//! table and are written as JSON (default `BENCH_resilience.json`,
//! override with the `RESILIENCE_BENCH_OUT` env var).
//!
//! Run: `cargo bench --bench resilience [requests]`

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, FleetHandle, Qos, RetryingSlot, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::BackendKind;
use spoga::testing::SplitMix64;

struct Row {
    scenario: &'static str,
    requests: usize,
    req_per_s: f64,
    resubmits: u64,
    recovery_ms: f64,
    /// p99 of per-request blocking latency; 0 for scenarios that submit
    /// their whole burst asynchronously up front (per-slot latency there
    /// would measure queue position, not service).
    p99_us: f64,
    /// Typed admission sheds (`Error::Overloaded`) observed by the clients.
    shed: u64,
}

/// p99 over a sorted-in-place latency sample; 0 for an empty one.
fn p99_us(lat_us: &mut Vec<u64>) -> f64 {
    if lat_us.is_empty() {
        return 0.0;
    }
    lat_us.sort_unstable();
    lat_us[(lat_us.len() - 1) * 99 / 100] as f64
}

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-resilience-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
         mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
         mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
    )
    .expect("write manifest");
    dir
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "edge_probe",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::fc("head", 6 * 6 * 4, 5),
        ],
    }
}

fn two_shards(dir: &str, window_s: f64) -> FleetConfig {
    let cfg = CoordinatorConfig {
        artifact_dir: dir.to_string(),
        workers: 2,
        max_batch_wait_s: window_s,
        ..Default::default()
    };
    FleetConfig {
        shards: vec![cfg.clone(), cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    }
}

fn submit_burst(h: &FleetHandle, requests: usize) -> Vec<RetryingSlot> {
    let mut rng = SplitMix64::new(5);
    let model = tiny_cnn();
    let mut slots = Vec::new();
    for i in 0..requests {
        match i % 3 {
            0 => {
                let a: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
                let b: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
                slots.push(h.submit_gemm_retrying("gemm_8x8x8", a, b).expect("gemm"));
            }
            1 => {
                let row: Vec<i32> = (0..16).map(|v| ((v + i) % 100) as i32).collect();
                slots.push(h.submit_mlp_retrying(row).expect("mlp"));
            }
            _ => {
                let seed = i as i32;
                let input: Vec<i32> =
                    (0..6 * 6 * 3).map(|v| ((v * 17 + seed * 7) % 251) - 125).collect();
                slots.push(h.submit_cnn_retrying(model.clone(), input).expect("cnn"));
            }
        }
    }
    slots
}

fn run_burst(dir: &str, requests: usize, kill_shard_0: bool) -> Row {
    // Same batching window for both scenarios, so the baseline-vs-failover
    // req/s gap measures the retry layer, not a window-length difference
    // (the kill path only needs the window long enough to hold accepted
    // jobs when the retire lands, which 50 ms satisfies).
    let fleet = Fleet::start(two_shards(dir, 0.05)).expect("fleet");
    let h = fleet.handle();
    h.infer_mlp(vec![0; 16]).expect("warm");
    let t0 = Instant::now();
    let slots = submit_burst(&h, requests);
    if kill_shard_0 {
        h.shard(0).retire_workers().expect("retire");
    }
    for s in slots {
        s.recv_timeout(Duration::from_secs(60)).expect("slot resolves");
    }
    let wall = t0.elapsed().as_secs_f64();
    let t = h.telemetry();
    let row = Row {
        scenario: if kill_shard_0 { "mid_flight_failover" } else { "baseline" },
        requests,
        req_per_s: requests as f64 / wall.max(1e-12),
        resubmits: t.resubmits,
        recovery_ms: 0.0,
        p99_us: 0.0,
        shed: t.shed(),
    };
    if kill_shard_0 {
        assert!(t.resubmits > 0, "failover bench never exercised a resubmission");
    }
    fleet.shutdown();
    row
}

fn run_revival(dir: &str) -> Row {
    let fleet = Fleet::start(two_shards(dir, 0.002)).expect("fleet");
    let h = fleet.handle();
    h.infer_mlp(vec![0; 16]).expect("warm");
    h.shard(0).retire_workers().expect("retire");
    // Wait until the retirement lands (gauge drops) before timing revival.
    while h.shard_stats(0).live_workers.load(Ordering::Relaxed) > 0 {
        std::thread::sleep(Duration::from_micros(50));
    }
    let t0 = Instant::now();
    assert!(h.revive_shard(0), "revival must succeed");
    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    let row = Row {
        scenario: "revival",
        requests: 0,
        req_per_s: 0.0,
        resubmits: 0,
        recovery_ms,
        p99_us: 0.0,
        shed: 0,
    };
    fleet.shutdown();
    row
}

/// Overload scenario: blocking per-request clients against a 1-shard fleet
/// with a tight ingress bound and a best-effort admission watermark. High
/// traffic is held (never shed — the bound cannot fill under blocking
/// clients), BestEffort sheds typed whenever the outstanding depth sits at
/// the watermark. `req_per_s` counts attempts over wall-clock; `p99_us`
/// covers the served requests.
fn run_overload(dir: &str, requests: usize, best_effort: bool) -> Row {
    let cfg = CoordinatorConfig {
        artifact_dir: dir.to_string(),
        workers: 2,
        max_batch_wait_s: 0.002,
        queue_depth: 4,
        best_effort_watermark: Some(2),
        ..Default::default()
    };
    let fleet = Fleet::start(FleetConfig {
        shards: vec![cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .expect("fleet");
    let h = fleet.handle();
    h.infer_mlp(vec![0; 16]).expect("warm");
    let clients = 4usize;
    let per = (requests / clients).max(1);
    let t0 = Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut lat_us: Vec<u64> = Vec::new();
                let mut shed = 0u64;
                for i in 0..per {
                    let row: Vec<i32> = (0..16).map(|v| ((v + i + t) % 100) as i32).collect();
                    let qos = if best_effort { Qos::best_effort() } else { Qos::default() };
                    let s0 = Instant::now();
                    match h.submit_mlp_qos(row, qos) {
                        Ok(rx) => {
                            rx.recv_timeout(Duration::from_secs(60))
                                .expect("slot resolves")
                                .expect("accepted request serves");
                            lat_us.push(s0.elapsed().as_micros() as u64);
                        }
                        Err(spoga::Error::Overloaded(_)) => shed += 1,
                        Err(e) => panic!("unexpected refusal: {e}"),
                    }
                }
                (lat_us, shed)
            })
        })
        .collect();
    let mut lat_us: Vec<u64> = Vec::new();
    let mut shed = 0u64;
    for j in joins {
        let (l, s) = j.join().unwrap();
        lat_us.extend(l);
        shed += s;
    }
    let wall = t0.elapsed().as_secs_f64();
    let attempts = clients * per;
    let row = Row {
        scenario: if best_effort { "overload_best_effort" } else { "overload_high" },
        requests: attempts,
        req_per_s: attempts as f64 / wall.max(1e-12),
        resubmits: 0,
        recovery_ms: 0.0,
        p99_us: p99_us(&mut lat_us),
        shed,
    };
    fleet.shutdown();
    row
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(384);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();
    println!("resilience: {requests} mixed retrying requests over 2 software shards\n");

    let rows = vec![
        run_burst(&artifact_dir, requests, false),
        run_burst(&artifact_dir, requests, true),
        run_revival(&artifact_dir),
        run_overload(&artifact_dir, requests, false),
        run_overload(&artifact_dir, requests, true),
    ];

    let mut t = Table::new(vec![
        "scenario", "requests", "req/s", "resubmits", "recovery ms", "p99 us", "shed",
    ]);
    for r in &rows {
        t.row(vec![
            r.scenario.to_string(),
            r.requests.to_string(),
            fmt_sig(r.req_per_s, 3),
            r.resubmits.to_string(),
            format!("{:.2}", r.recovery_ms),
            format!("{:.0}", r.p99_us),
            r.shed.to_string(),
        ]);
    }
    println!("{}", t.render());

    // ---- JSON trajectory record ---------------------------------------------
    let out_path = std::env::var("RESILIENCE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_resilience.json".to_string());
    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"scenario\": \"{}\", \"requests\": {}, \"req_per_s\": {:.1}, \
                 \"resubmits\": {}, \"recovery_ms\": {:.3}, \"p99_us\": {:.1}, \
                 \"shed\": {}}}",
                r.scenario, r.requests, r.req_per_s, r.resubmits, r.recovery_ms, r.p99_us,
                r.shed
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"resilience\",\n  \"requests\": {requests},\n  \
         \"workload\": \"mixed GEMM/MLP/CNN retrying slots; shard 0 killed mid-window; revival timed; QoS overload held-vs-shed\",\n  \
         \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
