//! Bench: resilience-layer costs — mid-flight failover throughput and
//! shard recovery time.
//!
//! Scenario `baseline`: a mixed retrying-slot burst against a healthy
//! 2-shard software fleet. Scenario `mid_flight_failover`: the same burst,
//! but shard 0's worker pool is killed while its batching window still
//! holds accepted jobs — every slot must resolve on the survivor, and the
//! gap between the two `req_per_s` figures is the failover tax. Scenario
//! `revival` measures wall-clock from `revive_shard` to a serving pool
//! (worker respawn + engine warmup + health probe).
//!
//! Self-contained (synthetic manifest in a temp dir). Results print as a
//! table and are written as JSON (default `BENCH_resilience.json`,
//! override with the `RESILIENCE_BENCH_OUT` env var).
//!
//! Run: `cargo bench --bench resilience [requests]`

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, FleetHandle, RetryingSlot, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::BackendKind;
use spoga::testing::SplitMix64;

struct Row {
    scenario: &'static str,
    requests: usize,
    req_per_s: f64,
    resubmits: u64,
    recovery_ms: f64,
}

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-resilience-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
         mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
         mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
    )
    .expect("write manifest");
    dir
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "edge_probe",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::fc("head", 6 * 6 * 4, 5),
        ],
    }
}

fn two_shards(dir: &str, window_s: f64) -> FleetConfig {
    let cfg = CoordinatorConfig {
        artifact_dir: dir.to_string(),
        workers: 2,
        max_batch_wait_s: window_s,
        ..Default::default()
    };
    FleetConfig {
        shards: vec![cfg.clone(), cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    }
}

fn submit_burst(h: &FleetHandle, requests: usize) -> Vec<RetryingSlot> {
    let mut rng = SplitMix64::new(5);
    let model = tiny_cnn();
    let mut slots = Vec::new();
    for i in 0..requests {
        match i % 3 {
            0 => {
                let a: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
                let b: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
                slots.push(h.submit_gemm_retrying("gemm_8x8x8", a, b).expect("gemm"));
            }
            1 => {
                let row: Vec<i32> = (0..16).map(|v| ((v + i) % 100) as i32).collect();
                slots.push(h.submit_mlp_retrying(row).expect("mlp"));
            }
            _ => {
                let seed = i as i32;
                let input: Vec<i32> =
                    (0..6 * 6 * 3).map(|v| ((v * 17 + seed * 7) % 251) - 125).collect();
                slots.push(h.submit_cnn_retrying(model.clone(), input).expect("cnn"));
            }
        }
    }
    slots
}

fn run_burst(dir: &str, requests: usize, kill_shard_0: bool) -> Row {
    // Same batching window for both scenarios, so the baseline-vs-failover
    // req/s gap measures the retry layer, not a window-length difference
    // (the kill path only needs the window long enough to hold accepted
    // jobs when the retire lands, which 50 ms satisfies).
    let fleet = Fleet::start(two_shards(dir, 0.05)).expect("fleet");
    let h = fleet.handle();
    h.infer_mlp(vec![0; 16]).expect("warm");
    let t0 = Instant::now();
    let slots = submit_burst(&h, requests);
    if kill_shard_0 {
        h.shard(0).retire_workers().expect("retire");
    }
    for s in slots {
        s.recv_timeout(Duration::from_secs(60)).expect("slot resolves");
    }
    let wall = t0.elapsed().as_secs_f64();
    let t = h.telemetry();
    let row = Row {
        scenario: if kill_shard_0 { "mid_flight_failover" } else { "baseline" },
        requests,
        req_per_s: requests as f64 / wall.max(1e-12),
        resubmits: t.resubmits,
        recovery_ms: 0.0,
    };
    if kill_shard_0 {
        assert!(t.resubmits > 0, "failover bench never exercised a resubmission");
    }
    fleet.shutdown();
    row
}

fn run_revival(dir: &str) -> Row {
    let fleet = Fleet::start(two_shards(dir, 0.002)).expect("fleet");
    let h = fleet.handle();
    h.infer_mlp(vec![0; 16]).expect("warm");
    h.shard(0).retire_workers().expect("retire");
    // Wait until the retirement lands (gauge drops) before timing revival.
    while h.shard_stats(0).live_workers.load(Ordering::Relaxed) > 0 {
        std::thread::sleep(Duration::from_micros(50));
    }
    let t0 = Instant::now();
    assert!(h.revive_shard(0), "revival must succeed");
    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    let row = Row {
        scenario: "revival",
        requests: 0,
        req_per_s: 0.0,
        resubmits: 0,
        recovery_ms,
    };
    fleet.shutdown();
    row
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(384);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();
    println!("resilience: {requests} mixed retrying requests over 2 software shards\n");

    let rows = vec![
        run_burst(&artifact_dir, requests, false),
        run_burst(&artifact_dir, requests, true),
        run_revival(&artifact_dir),
    ];

    let mut t = Table::new(vec!["scenario", "requests", "req/s", "resubmits", "recovery ms"]);
    for r in &rows {
        t.row(vec![
            r.scenario.to_string(),
            r.requests.to_string(),
            fmt_sig(r.req_per_s, 3),
            r.resubmits.to_string(),
            format!("{:.2}", r.recovery_ms),
        ]);
    }
    println!("{}", t.render());

    // ---- JSON trajectory record ---------------------------------------------
    let out_path = std::env::var("RESILIENCE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_resilience.json".to_string());
    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"scenario\": \"{}\", \"requests\": {}, \"req_per_s\": {:.1}, \
                 \"resubmits\": {}, \"recovery_ms\": {:.3}}}",
                r.scenario, r.requests, r.req_per_s, r.resubmits, r.recovery_ms
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"resilience\",\n  \"requests\": {requests},\n  \
         \"workload\": \"mixed GEMM/MLP/CNN retrying slots; shard 0 killed mid-window; revival timed\",\n  \
         \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
