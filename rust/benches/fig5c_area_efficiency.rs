//! Bench: regenerate paper **Fig. 5(c)** — FPS/W/mm² (area efficiency over
//! the electronic/CMOS die, the area the paper's Table II models).
//!
//! The paper quotes its headline factors at 1 GS/s (SPOGA_1 vs *_1): that
//! is where SPOGA's converter advantage peaks — its 16 ADCs/core vs the
//! baselines' M ADCs + DEAS + intermediate SRAM. At 10 GS/s SPOGA's 2N
//! input DACs erode the advantage, which this bench also shows.
//!
//! Run: `cargo bench --bench fig5c_area_efficiency`

use spoga::benchkit::bench;
use spoga::metrics::{build_figure, Metric, FIG5_CORES};
use spoga::report::{fmt_ratio, fmt_sig, Table};
use spoga::units::DataRate;

fn main() {
    let fig = build_figure(Metric::FpsPerWPerMm2, &DataRate::ALL, FIG5_CORES).unwrap();

    let mut header = vec!["Variant".to_string()];
    header.extend(fig.models.iter().cloned());
    header.push("gmean".into());
    let mut t = Table::new(header);
    for v in &fig.variants {
        let mut row = vec![v.name.clone()];
        row.extend(v.per_model.iter().map(|x| fmt_sig(*x, 3)));
        row.push(fmt_sig(v.gmean, 3));
        t.row(row);
    }
    println!(
        "Fig. 5(c) — FPS/W/mm² (CMOS die), {} cores/accelerator:\n{}",
        FIG5_CORES,
        t.render()
    );

    let mut t = Table::new(vec!["gmean ratio", "ours", "paper"]);
    for (a, b, paper) in [
        ("SPOGA_1", "DEAPCNN_1", 28.5),
        ("SPOGA_1", "HOLYLIGHT_1", 22.2),
    ] {
        let r = fig.gmean_ratio(a, b).unwrap();
        t.row(vec![format!("{a} / {b}"), fmt_ratio(r), fmt_ratio(paper)]);
    }
    println!("headline factors (at 1 GS/s, as in the paper):\n{}", t.render());

    let stats = bench(1, 10, || {
        build_figure(Metric::FpsPerWPerMm2, &DataRate::ALL, FIG5_CORES).unwrap()
    });
    println!("simulator: {stats}");
}
