//! Bench: whole-CNN serving hot path — the legacy wire-format lowering
//! (`run_cnn_batch_keyed_reference`: per-request im2col allocation,
//! i8→i32→i8 wire round-trips, per-plan weight revalidation) vs the
//! compiled-plan path (`run_cnn_batch_keyed`: compile-time `PackedB`
//! weights, persistent scratch arena, direct-i8 backend entry), across
//! batch ∈ {1, 4, 16} and the scalar vs SIMD micro-kernels (plus AVX2 rows
//! when the host detects it).
//!
//! Results are printed as a table and written as JSON (default
//! `BENCH_cnn.json`, override with the `CNN_BENCH_OUT` env var) so future
//! perf PRs have a trajectory baseline. The committed snapshot stays
//! `pending-first-run` (schema guarded by `rust/tests/bench_schema.rs`)
//! until a toolchain host runs this.
//!
//! Run: `cargo bench --bench cnn_hotpath [iter_scale]`
//! (`iter_scale` defaults to 1; pass 0 for a single-iteration smoke pass.)

use spoga::benchkit::bench;
use spoga::bitslice::{avx2_available, set_micro_override, MicroKernel};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::report::{fmt_ratio, fmt_sig, Table};
use spoga::runtime::{run_cnn_batch_keyed, run_cnn_batch_keyed_reference, Engine};

/// An edge-CNN-shaped model: strided stem, depthwise + pointwise pair, FC
/// head — enough im2col/group/FC variety to exercise every serving arm.
fn bench_model() -> CnnModel {
    CnnModel {
        name: "bench_edge",
        layers: vec![
            Layer::conv("stem", 16, 16, 3, 16, 3, 2, 1),
            Layer::dwconv("dw1", 8, 8, 16, 3, 1, 1),
            Layer::conv("pw1", 8, 8, 16, 32, 1, 1, 0),
            Layer::fc("head", 8 * 8 * 32, 10),
        ],
    }
}

fn synthetic_dir() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("spoga-cnn-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
    dir
}

struct Row {
    path: &'static str,
    micro: &'static str,
    batch: usize,
    frames_per_s: f64,
    speedup_vs_legacy: f64,
}

fn main() {
    let iter_scale: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(1);
    let dir = synthetic_dir();
    let model = bench_model();
    let input_len = 16 * 16 * 3;
    let frames: Vec<Vec<i32>> = (0..16)
        .map(|f| (0..input_len).map(|v| (((v * 31) + f * 97) % 251) as i32 - 125).collect())
        .collect();

    // Smoke check before timing anything: the plan path must serve the
    // legacy path's logits bit for bit under every micro-kernel.
    for micro in [MicroKernel::Scalar, MicroKernel::Simd, MicroKernel::Avx2] {
        set_micro_override(Some(micro));
        let refs: Vec<&[i32]> = frames.iter().take(4).map(|f| f.as_slice()).collect();
        let mut plan_eng = Engine::new(&dir).unwrap();
        let mut ref_eng = Engine::new(&dir).unwrap();
        let planned = run_cnn_batch_keyed(&mut plan_eng, &model, &refs, &[]).unwrap();
        let legacy = run_cnn_batch_keyed_reference(&mut ref_eng, &model, &refs, &[]).unwrap();
        for (p, l) in planned.iter().zip(&legacy) {
            assert_eq!(p.logits, l.logits, "plan path diverged under {micro:?}");
        }
    }
    set_micro_override(None);

    let mut micros = vec![("scalar", MicroKernel::Scalar), ("simd", MicroKernel::Simd)];
    if avx2_available() {
        micros.push(("avx2", MicroKernel::Avx2));
    }
    println!(
        "CNN serving hot path: legacy wire lowering vs compiled plan (avx2 {})\n",
        if avx2_available() { "detected" } else { "absent" },
    );

    let mut rows: Vec<Row> = Vec::new();
    let mut t = Table::new(vec![
        "micro",
        "batch",
        "legacy (frames/s)",
        "plan (frames/s)",
        "plan vs legacy",
    ]);
    for &(micro_name, micro) in &micros {
        set_micro_override(Some(micro));
        for batch in [1usize, 4, 16] {
            let refs: Vec<&[i32]> = frames.iter().take(batch).map(|f| f.as_slice()).collect();
            // ~40 serving calls per timed cell at scale 1, floor of 1.
            let iters = (40 * iter_scale / batch).max(1);
            let warmup = 1;
            let mut ref_eng = Engine::new(&dir).unwrap();
            let legacy = bench(warmup, iters, || {
                run_cnn_batch_keyed_reference(&mut ref_eng, &model, &refs, &[]).unwrap()
            });
            let mut plan_eng = Engine::new(&dir).unwrap();
            let plan = bench(warmup, iters, || {
                run_cnn_batch_keyed(&mut plan_eng, &model, &refs, &[]).unwrap()
            });
            let legacy_fps = batch as f64 / legacy.min_s;
            let plan_fps = batch as f64 / plan.min_s;
            rows.push(Row {
                path: "legacy",
                micro: micro_name,
                batch,
                frames_per_s: legacy_fps,
                speedup_vs_legacy: 1.0,
            });
            rows.push(Row {
                path: "plan",
                micro: micro_name,
                batch,
                frames_per_s: plan_fps,
                speedup_vs_legacy: plan_fps / legacy_fps,
            });
            t.row(vec![
                micro_name.to_string(),
                batch.to_string(),
                fmt_sig(legacy_fps, 3),
                fmt_sig(plan_fps, 3),
                fmt_ratio(plan_fps / legacy_fps),
            ]);
        }
    }
    set_micro_override(None);
    println!("{}", t.render());

    // JSON snapshot for the perf trajectory.
    let out_path = std::env::var("CNN_BENCH_OUT").unwrap_or_else(|_| "BENCH_cnn.json".to_string());
    let cells: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"path\": \"{}\", \"micro\": \"{}\", \"batch\": {}, \
                 \"frames_per_s\": {:.3}, \"speedup_vs_legacy\": {:.3}}}",
                r.path, r.micro, r.batch, r.frames_per_s, r.speedup_vs_legacy
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"cnn_hotpath\",\n  \
         \"note\": \"acceptance: plan >= legacy frames/s at every (micro, batch) cell\",\n  \
         \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        cells.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
