//! Bench: wire-protocol round-trip overhead vs in-process submission.
//!
//! Row `in_process`: a blocking MLP burst against a 1-shard local fleet —
//! the submit/batch/execute/reply path with zero transport. Row
//! `loopback_tcp`: the same burst through a `ShardServer` + `RemoteShard`
//! pair over 127.0.0.1 — every request crosses the socket twice (frame
//! encode, FNV checksum, kernel loopback, decode) plus the client's
//! pending-map bookkeeping. `overhead_us` is the per-request mean-latency
//! gap between the two rows: the price of crossing a process boundary.
//!
//! Self-contained (synthetic manifest in a temp dir, port 0). Results
//! print as a table and are written as JSON (default `BENCH_net.json`,
//! override with the `NET_BENCH_OUT` env var).
//!
//! Run: `cargo bench --bench net_roundtrip [requests]`

use std::time::Instant;

use spoga::coordinator::{CoordinatorConfig, Fleet, FleetConfig, FleetHandle, RemoteShardConfig};
use spoga::net::{NetConfig, ServeTarget, ShardServer};
use spoga::report::{fmt_sig, Table};
use spoga::runtime::BackendKind;

struct Row {
    path: &'static str,
    requests: usize,
    req_per_s: f64,
    mean_us: f64,
    overhead_us: f64,
}

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("spoga-net-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
         mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
    )
    .expect("write manifest");
    dir
}

fn shard_cfg(artifact_dir: &str) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: artifact_dir.to_string(),
        workers: 2,
        backend: BackendKind::Software,
        max_batch_wait_s: 0.0,
        ..Default::default()
    }
}

/// Sequential blocking MLP burst: per-request latency is what the row
/// measures, so no client concurrency to hide the transport behind.
fn drive(h: &FleetHandle, requests: usize) -> (f64, f64) {
    h.infer_mlp(vec![0; 16]).expect("warm");
    let t0 = Instant::now();
    for i in 0..requests {
        let row: Vec<i32> = (0..16).map(|v| ((v + i) % 100) as i32).collect();
        h.infer_mlp(row).expect("infer");
    }
    let wall = t0.elapsed().as_secs_f64();
    (requests as f64 / wall.max(1e-12), wall / requests as f64 * 1e6)
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(512);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();
    println!("net_roundtrip: {requests} sequential MLP requests per path\n");

    // In-process baseline.
    let local = Fleet::single(shard_cfg(&artifact_dir)).expect("local fleet");
    let (local_rps, local_us) = drive(&local.handle(), requests);
    local.shutdown();

    // Loopback TCP: same shard shape behind a ShardServer, pure-remote
    // client fleet in front.
    let backend = Fleet::single(shard_cfg(&artifact_dir)).expect("backend fleet");
    let server = ShardServer::start(
        "127.0.0.1:0",
        ServeTarget::Fleet(backend.handle()),
        NetConfig::default(),
    )
    .expect("shard server");
    let remote = Fleet::start(FleetConfig {
        remotes: vec![RemoteShardConfig::new(server.local_addr().to_string())],
        ..Default::default()
    })
    .expect("remote fleet");
    let (remote_rps, remote_us) = drive(&remote.handle(), requests);
    remote.shutdown();
    server.shutdown();
    backend.shutdown();

    let rows = vec![
        Row {
            path: "in_process",
            requests,
            req_per_s: local_rps,
            mean_us: local_us,
            overhead_us: 0.0,
        },
        Row {
            path: "loopback_tcp",
            requests,
            req_per_s: remote_rps,
            mean_us: remote_us,
            overhead_us: remote_us - local_us,
        },
    ];

    let mut t = Table::new(vec!["path", "requests", "req/s", "mean us", "overhead us"]);
    for r in &rows {
        t.row(vec![
            r.path.to_string(),
            r.requests.to_string(),
            fmt_sig(r.req_per_s, 3),
            format!("{:.1}", r.mean_us),
            format!("{:.1}", r.overhead_us),
        ]);
    }
    println!("{}", t.render());

    // ---- JSON trajectory record ---------------------------------------------
    let out_path =
        std::env::var("NET_BENCH_OUT").unwrap_or_else(|_| "BENCH_net.json".to_string());
    let json_rows: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "    {{\"path\": \"{}\", \"requests\": {}, \"req_per_s\": {:.1}, \
                 \"mean_us\": {:.2}, \"overhead_us\": {:.2}}}",
                r.path, r.requests, r.req_per_s, r.mean_us, r.overhead_us
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"net_roundtrip\",\n  \"requests\": {requests},\n  \
         \"workload\": \"sequential blocking MLP burst, in-process vs loopback-TCP shard server\",\n  \
         \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        json_rows.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
