//! Bench: the noise-aware serving frontier — served accuracy vs projected
//! sim-FPS/W over the K × ADC-bits grid (`NoiseSweepGrid::paper_range`),
//! with wall-clock serving throughput per cell.
//!
//! One noise-injecting photonic shard per grid cell serves t-stacked CNN
//! probe frames of its own K-length dot products (batching stays ON under
//! noise — per-row attribution keeps every frame's events exact), so the
//! numbers answer: what does each point of the paper's spatial-parallelism
//! × ADC-resolution plane cost in served accuracy, projected efficiency,
//! and host-side serving rate?
//!
//! Self-contained (synthetic manifest in a temp dir; no `make artifacts`).
//! Results print as a table and are written as JSON (default
//! `BENCH_noise.json`, override with the `NOISE_BENCH_OUT` env var).
//!
//! Run: `cargo bench --bench noise_frontier [frames_per_cell]`

use std::sync::atomic::Ordering;
use std::time::Instant;

use spoga::coordinator::{CoordinatorConfig, Fleet, FleetConfig, NoiseSweepGrid};
use spoga::report::{fmt_sig, Table};
use spoga::runtime::{BackendKind, PhotonicConfig};

struct CellResult {
    k: usize,
    adc_bits: u32,
    req_per_s: f64,
    served_exact: f64,
    noise_events: u64,
    lanes: u64,
    sim_fps: f64,
    sim_fps_per_w: f64,
    cnn_batches: u64,
}

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-noise-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(dir.join("manifest.txt"), "mlp_b1 m.hlo.txt i32:1x16 i32:1x4\n")
        .expect("write manifest");
    dir
}

fn main() {
    let frames: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(64);
    let grid = NoiseSweepGrid::paper_range();
    let dir = synthetic_artifacts();
    let base = CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        backend: BackendKind::Photonic(PhotonicConfig::spoga()),
        max_batch_wait_s: 0.002,
        ..Default::default()
    };
    println!(
        "noise frontier: K ∈ {:?} × adc bits ∈ {:?}, margin +{:.0} dB, \
         {frames} t-stacked CNN probe frames per cell\n",
        grid.ks, grid.adc_bits, grid.margin_db
    );

    let fleet = Fleet::start(FleetConfig::noise_grid(base, &grid)).expect("noise-grid fleet");
    let h = fleet.handle();
    // Warm every cell before timing (plans compile on first frame).
    grid.drive(&h, 1).expect("warmup frame");

    let cells = grid.cells();
    let mut results = Vec::with_capacity(cells.len());
    for (i, &(k, adc_bits)) in cells.iter().enumerate() {
        let before = spoga::metrics::ShardTelemetry::capture("pre", &h.shard_stats(i));
        let batches_before = h.shard_stats(i).cnn_batches.load(Ordering::Relaxed);
        let t0 = Instant::now();
        let served = grid.drive_cell(&h, i, frames).expect("cell traffic");
        let wall = t0.elapsed().as_secs_f64();
        let after = spoga::metrics::ShardTelemetry::capture("post", &h.shard_stats(i));
        let (lanes, noise) =
            (after.lanes - before.lanes, after.noise_events - before.noise_events);
        results.push(CellResult {
            k,
            adc_bits,
            req_per_s: served as f64 / wall.max(1e-12),
            served_exact: spoga::metrics::exact_fraction(noise, lanes),
            noise_events: noise,
            lanes,
            sim_fps: spoga::metrics::per_unit(
                after.sim_reports - before.sim_reports,
                after.sim_latency_s - before.sim_latency_s,
            ),
            sim_fps_per_w: spoga::metrics::per_unit(
                after.sim_reports - before.sim_reports,
                after.energy_j - before.energy_j,
            ),
            cnn_batches: h.shard_stats(i).cnn_batches.load(Ordering::Relaxed) - batches_before,
        });
    }
    let total_batches: u64 = results.iter().map(|r| r.cnn_batches).sum();
    assert!(total_batches > 0, "stacked CNN batching must stay on under noise");
    fleet.shutdown();

    let mut t = Table::new(vec![
        "K",
        "adc bits",
        "req/s",
        "served-exact",
        "noise events",
        "lanes",
        "sim FPS",
        "sim FPS/W",
    ]);
    for r in &results {
        t.row(vec![
            r.k.to_string(),
            r.adc_bits.to_string(),
            fmt_sig(r.req_per_s, 3),
            format!("{:.6}", r.served_exact),
            r.noise_events.to_string(),
            r.lanes.to_string(),
            fmt_sig(r.sim_fps, 3),
            fmt_sig(r.sim_fps_per_w, 3),
        ]);
    }
    println!("{}", t.render());

    // ---- JSON trajectory record ---------------------------------------------
    let out_path = std::env::var("NOISE_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_noise.json".to_string());
    let rows: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "    {{\"k\": {}, \"adc_bits\": {}, \"req_per_s\": {:.1}, \
                 \"served_exact\": {:.6}, \"noise_events\": {}, \"lanes\": {}, \
                 \"sim_fps\": {:.3e}, \"sim_fps_per_w\": {:.3e}}}",
                r.k, r.adc_bits, r.req_per_s, r.served_exact, r.noise_events, r.lanes,
                r.sim_fps, r.sim_fps_per_w
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"noise_frontier\",\n  \"frames_per_cell\": {frames},\n  \
         \"margin_db\": {:.1},\n  \
         \"workload\": \"t-stacked CNN probe frames, 1xKx{} GEMM per frame, \
         noisy SPOGA_10 shards\",\n  \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        grid.margin_db,
        NoiseSweepGrid::PROBE_OUTPUTS,
        rows.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
