//! Ablation: how much of SPOGA's win comes from the **extended
//! optical-analog dataflow** (paper §III-B) vs its raw parallelism?
//!
//! We re-run SPOGA with the prior-work post-processing forced back on —
//! per-pass digitization (ADC every K-chunk), intermediate SRAM traffic and
//! DEAS recombination — exactly the overheads the PWAB eliminates, while
//! keeping N, M and the link budget unchanged. The residual gap to the real
//! SPOGA isolates the dataflow contribution.
//!
//! Run: `cargo bench --bench ablation_dataflow`

use spoga::arch::accel::Accelerator;
use spoga::arch::core::{Core, GemmPlan};
use spoga::arch::cost::EnergyBreakdown;
use spoga::dnn::models::CnnModel;
use spoga::metrics::gmean;
use spoga::optics::link_budget::ArchClass;
use spoga::report::{fmt_ratio, fmt_sig, Table};
use spoga::units::DataRate;

/// SPOGA plan with the extended analog dataflow DISABLED: every K-pass is
/// digitized per DPU, intermediates go through SRAM, DEAS recombines.
fn ablated_plan(core: &Core, shape: &spoga::dnn::layer::GemmShape) -> GemmPlan {
    let native = core.plan_gemm(shape);
    let k_chunks = shape.k.div_ceil(core.n) as u64;
    let outputs = shape.outputs();
    // 4 nibble-product intermediates per output per pass must be digitized
    // (no homodyne lane merging, no charge accumulation across passes).
    let adc = 4 * outputs * k_chunks;
    GemmPlan {
        adc_conversions: adc,
        bpca_cycles: 0,
        deas_outputs: outputs,
        sram_bytes: 2 * adc,
        ..native
    }
}

fn frame_energy(core: &Core, model: &CnnModel, ablated: bool) -> (f64, f64) {
    // Returns (latency_s, energy_j) for a 64-core fleet.
    let cores = 64u64;
    let mut latency = 0.0;
    let mut energy = EnergyBreakdown::default();
    for layer in &model.layers {
        let shape = layer.gemm();
        let plan =
            if ablated { ablated_plan(core, &shape) } else { core.plan_gemm(&shape) };
        let steps = plan.timesteps.div_ceil(cores);
        latency += steps as f64 * core.dr.step_seconds();
        if plan.deas_outputs > 0 {
            latency += spoga::devices::Deas::default().fill_latency_s(core.dr);
        }
        energy.add(&EnergyBreakdown::of_plan(core, &plan));
    }
    (latency, energy.total_j())
}

fn main() {
    let models = CnnModel::paper_benchmarks();
    let mut t = Table::new(vec![
        "Variant",
        "gmean FPS",
        "gmean FPS/W",
        "FPS/W vs native",
    ]);
    for dr in [DataRate::Gs1, DataRate::Gs10] {
        let core = Core::design(ArchClass::Mwa, dr, 10.0).unwrap();
        let mut rows = Vec::new();
        for ablated in [false, true] {
            let fps: Vec<f64> =
                models.iter().map(|m| 1.0 / frame_energy(&core, m, ablated).0).collect();
            let fpw: Vec<f64> =
                models.iter().map(|m| 1.0 / frame_energy(&core, m, ablated).1).collect();
            rows.push((ablated, gmean(&fps), gmean(&fpw)));
        }
        let native_fpw = rows[0].2;
        for (ablated, fps, fpw) in rows {
            t.row(vec![
                format!(
                    "SPOGA_{}{}",
                    dr.suffix(),
                    if ablated { " (DEAS post-processing forced)" } else { " (native PWAB)" }
                ),
                fmt_sig(fps, 3),
                fmt_sig(fpw, 3),
                fmt_ratio(fpw / native_fpw),
            ]);
        }
    }
    println!(
        "Ablation — value of the extended optical-analog dataflow (§III-B):\n{}",
        t.render()
    );

    // Secondary ablation: iso-laser-power vs equal-core normalization.
    let mut t = Table::new(vec!["Normalization", "S/D FPS ratio @10GS/s"]);
    for (label, accel_s, accel_d) in [
        (
            "equal cores (64)",
            Accelerator::equal_cores(ArchClass::Mwa, DataRate::Gs10, 64).unwrap(),
            Accelerator::equal_cores(ArchClass::Amw, DataRate::Gs10, 64).unwrap(),
        ),
        (
            "iso laser power (60 W)",
            Accelerator::iso_laser_power(ArchClass::Mwa, DataRate::Gs10, 60.0).unwrap(),
            Accelerator::iso_laser_power(ArchClass::Amw, DataRate::Gs10, 60.0).unwrap(),
        ),
    ] {
        let fps = |a: &Accelerator| {
            let v: Vec<f64> = models
                .iter()
                .map(|m| spoga::sim::engine::simulate_frame(a, &m.workload()).fps())
                .collect();
            gmean(&v)
        };
        t.row(vec![label.to_string(), fmt_ratio(fps(&accel_s) / fps(&accel_d))]);
    }
    println!("Normalization sensitivity (DESIGN.md §5.2 knob):\n{}", t.render());

    // Mapping-strategy ablation (paper §II-B): best tile order per layer
    // class, with weight-reload overhead accounted.
    use spoga::dnn::layer::GemmShape;
    use spoga::sim::mapper::{evaluate, Mapping};
    let core = Core::design(ArchClass::Mwa, DataRate::Gs10, 10.0).unwrap();
    let shapes = [
        ("conv 3x3 (56x56x64->128)", GemmShape { t: 3136, k: 576, c: 128, groups: 1 }),
        ("pointwise (14x14x512->512)", GemmShape { t: 196, k: 512, c: 512, groups: 1 }),
        ("depthwise 3x3 (112x112x96)", GemmShape { t: 12544, k: 9, c: 1, groups: 96 }),
        ("fc 2048->1000 (batch 1)", GemmShape { t: 1, k: 2048, c: 1000, groups: 1 }),
    ];
    let mut t = Table::new(vec!["Layer class", "mapping", "compute eff.", "weight writes"]);
    for (label, sh) in shapes {
        for m in Mapping::ALL {
            let c = evaluate(&core, &sh, m);
            t.row(vec![
                label.to_string(),
                c.mapping.name().to_string(),
                format!("{:.3}", c.compute_efficiency()),
                format!("{:.2e}", c.weight_writes as f64),
            ]);
        }
    }
    println!("Mapping strategies on SPOGA_10 (§II-B ablation):\n{}", t.render());
}
