//! Bench: fleet throughput scaling over shard count and routing policy.
//!
//! Measures the same mixed MLP + GEMM + CNN client load against fleets of
//! 1, 2 and 4 software shards (round-robin), plus a 2-shard
//! software|photonic weighted split — the question: how much serving
//! throughput does each added coordinator shard buy on this host, and what
//! does heterogeneous A/B routing cost?
//!
//! Self-contained (synthetic manifest in a temp dir; no `make artifacts`).
//! Results print as a table and are written as JSON (default
//! `BENCH_fleet.json`, override with the `FLEET_BENCH_OUT` env var).
//!
//! Run: `cargo bench --bench fleet_scaling [requests]`

use std::sync::atomic::Ordering;
use std::time::Instant;

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, FleetHandle, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::testing::SplitMix64;

struct FleetResult {
    label: String,
    shards: usize,
    req_per_s: f64,
    p99_us: f64,
    cnn_batches: u64,
}

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-fleet-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_64x64x64 gemm.hlo.txt i32:64x64,i32:64x64 i32:64x64\n\
         mlp_b1 mlp_b1.hlo.txt i32:1x784 i32:1x10\n\
         mlp_b8 mlp_b8.hlo.txt i32:8x784 i32:8x10\n\
         mlp_b32 mlp_b32.hlo.txt i32:32x784 i32:32x10\n",
    )
    .expect("write manifest");
    dir
}

fn edge_cnn() -> CnnModel {
    CnnModel {
        name: "edge_net",
        layers: vec![
            Layer::conv("stem", 16, 16, 3, 16, 3, 2, 1),
            Layer::dwconv("dw1", 8, 8, 16, 3, 1, 1),
            Layer::conv("pw1", 8, 8, 16, 32, 1, 1, 0),
            Layer::fc("head", 8 * 8 * 32, 10),
        ],
    }
}

fn drive(h: &FleetHandle, requests: usize, model: &CnnModel) -> f64 {
    let clients = 8usize;
    let per = (requests / clients).max(1);
    let t0 = Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|cl| {
            let h = h.clone();
            let model = model.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(cl as u64 + 1);
                let cnn_input: Vec<i32> = (0..16 * 16 * 3).map(|v| (v % 251) - 125).collect();
                for i in 0..per {
                    let row: Vec<i32> = (0..784).map(|_| rng.below(128) as i32).collect();
                    h.infer_mlp(row).expect("mlp");
                    if i % 4 == 0 {
                        let a: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
                        let b: Vec<i32> = (0..64 * 64).map(|_| rng.i8() as i32).collect();
                        h.gemm("gemm_64x64x64", a, b).expect("gemm");
                    }
                    if i % 8 == 0 {
                        h.infer_cnn(model.clone(), cnn_input.clone()).expect("cnn");
                    }
                }
            })
        })
        .collect();
    joins.into_iter().for_each(|j| j.join().unwrap());
    t0.elapsed().as_secs_f64()
}

fn bench_fleet(
    label: &str,
    cfg: FleetConfig,
    requests: usize,
    model: &CnnModel,
) -> FleetResult {
    let shards = cfg.shards.len();
    let fleet = Fleet::start(cfg).expect("fleet");
    let h = fleet.handle();
    // Warm the pipeline before timing.
    h.infer_mlp(vec![0; 784]).expect("warm");

    let wall = drive(&h, requests, model);
    let t = h.telemetry();
    let served = t.completed();
    let p99 = (0..h.shard_count())
        .map(|i| h.shard_stats(i).latency_percentile(0.99))
        .fold(0.0f64, f64::max);
    let cnn_batches = (0..h.shard_count())
        .map(|i| h.shard_stats(i).cnn_batches.load(Ordering::Relaxed))
        .sum();
    assert_eq!(t.failed(), 0, "{label}: failures under load");
    let res = FleetResult {
        label: label.to_string(),
        shards,
        req_per_s: served as f64 / wall,
        p99_us: p99 * 1e6,
        cnn_batches,
    };
    fleet.shutdown();
    res
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(512);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();
    let model = edge_cnn();
    let shard = |backend: BackendKind| CoordinatorConfig {
        artifact_dir: artifact_dir.clone(),
        workers: 2,
        backend,
        max_batch_wait_s: 0.002,
        ..Default::default()
    };
    println!("fleet scaling: mixed MLP/GEMM/CNN load, 8 clients, {requests} rows base\n");

    let mut results = Vec::new();
    for n in [1usize, 2, 4] {
        results.push(bench_fleet(
            &format!("software_x{n}"),
            FleetConfig::replicated(shard(BackendKind::Software), n),
            requests,
            &model,
        ));
    }
    results.push(bench_fleet(
        "software|spoga_1to1",
        FleetConfig::ab_split(
            shard(BackendKind::Software),
            shard(BackendKind::Photonic(PhotonicConfig::spoga())),
            1,
            1,
        ),
        requests,
        &model,
    ));

    let mut t = Table::new(vec![
        "Fleet",
        "shards",
        "req/s",
        "p99 µs",
        "stacked CNN batches",
    ]);
    for r in &results {
        t.row(vec![
            r.label.clone(),
            r.shards.to_string(),
            fmt_sig(r.req_per_s, 3),
            format!("{:.0}", r.p99_us),
            r.cnn_batches.to_string(),
        ]);
    }
    println!("{}", t.render());
    let speedup = results[2].req_per_s / results[0].req_per_s.max(1e-9);
    println!("scaling: 4 shards serve {speedup:.2}x the 1-shard rate\n");

    // ---- JSON trajectory record ---------------------------------------------
    let out_path = std::env::var("FLEET_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_fleet.json".to_string());
    let rows: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "    {{\"fleet\": \"{}\", \"shards\": {}, \"req_per_s\": {:.1}, \
                 \"p99_us\": {:.1}, \"cnn_batches\": {}}}",
                r.label, r.shards, r.req_per_s, r.p99_us, r.cnn_batches
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"fleet_scaling\",\n  \"requests\": {requests},\n  \
         \"workload\": \"784-feature MLP rows + 64^3 GEMMs + edge_net CNN frames (8 clients)\",\n  \
         \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
