//! Bench: regenerate paper **Fig. 5(b)** — FPS/W (energy efficiency).
//!
//! Run: `cargo bench --bench fig5b_fps_per_watt`

use spoga::benchkit::bench;
use spoga::metrics::{build_figure, Metric, FIG5_CORES};
use spoga::report::{fmt_ratio, fmt_sig, Table};
use spoga::units::DataRate;

fn main() {
    let fig = build_figure(Metric::FpsPerW, &DataRate::ALL, FIG5_CORES).unwrap();

    let mut header = vec!["Variant".to_string()];
    header.extend(fig.models.iter().cloned());
    header.push("gmean".into());
    let mut t = Table::new(header);
    for v in &fig.variants {
        let mut row = vec![v.name.clone()];
        row.extend(v.per_model.iter().map(|x| fmt_sig(*x, 3)));
        row.push(fmt_sig(v.gmean, 3));
        t.row(row);
    }
    println!(
        "Fig. 5(b) — FPS/W, {} cores/accelerator:\n{}",
        FIG5_CORES,
        t.render()
    );

    let mut t = Table::new(vec!["gmean ratio", "ours", "paper"]);
    for (a, b, paper) in [
        ("SPOGA_10", "DEAPCNN_10", 2.0),
        ("SPOGA_10", "HOLYLIGHT_10", 1.3),
    ] {
        let r = fig.gmean_ratio(a, b).unwrap();
        t.row(vec![format!("{a} / {b}"), fmt_ratio(r), fmt_ratio(paper)]);
    }
    println!("headline factors:\n{}", t.render());

    let stats =
        bench(1, 10, || build_figure(Metric::FpsPerW, &DataRate::ALL, FIG5_CORES).unwrap());
    println!("simulator: {stats}");
}
