//! Bench: software vs photonic-sim execution backends under serving load.
//!
//! Measures, per backend: coordinator throughput (req/s, wall clock), mean
//! worker service time, and — for the photonic backends — the projected
//! sim-FPS / sim-FPS-per-watt the served traffic reports through its
//! per-request `ExecReport` telemetry. The question this answers: how much
//! serving throughput does photonic-in-the-loop telemetry cost, and what do
//! the design points project for identical traffic?
//!
//! Self-contained (synthetic manifest in a temp dir; no `make artifacts`).
//! Results print as a table and are written as JSON (default
//! `BENCH_backends.json`, override with the `BACKEND_BENCH_OUT` env var).
//!
//! Run: `cargo bench --bench coordinator_backend_matrix [requests]`

use std::sync::atomic::Ordering;
use std::time::Instant;

use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::metrics::LiveTelemetry;
use spoga::report::{fmt_sig, Table};
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::testing::SplitMix64;

struct BackendResult {
    label: String,
    req_per_s: f64,
    service_mean_us: f64,
    sim_fps: f64,
    sim_fps_per_w: f64,
}

fn synthetic_artifacts() -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spoga-backend-bench-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp artifact dir");
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_64x64x64 gemm.hlo.txt i32:64x64,i32:64x64 i32:64x64\n\
         mlp_b1 mlp_b1.hlo.txt i32:1x784 i32:1x10\n\
         mlp_b8 mlp_b8.hlo.txt i32:8x784 i32:8x10\n\
         mlp_b32 mlp_b32.hlo.txt i32:32x784 i32:32x10\n",
    )
    .expect("write manifest");
    dir
}

fn bench_backend(
    label: &str,
    kind: BackendKind,
    artifact_dir: &str,
    requests: usize,
    model: &CnnModel,
) -> BackendResult {
    let c = Coordinator::start(CoordinatorConfig {
        artifact_dir: artifact_dir.to_string(),
        workers: 2,
        backend: kind,
        max_batch_wait_s: 0.003,
        ..Default::default()
    })
    .expect("coordinator");
    let h = c.handle();
    // Warm the pipeline before timing.
    h.infer_mlp(vec![0; 784]).expect("warm");

    let clients = 8usize;
    let per = requests / clients;
    let t0 = Instant::now();
    let joins: Vec<_> = (0..clients)
        .map(|cl| {
            let h = h.clone();
            std::thread::spawn(move || {
                let mut rng = SplitMix64::new(cl as u64 + 1);
                for _ in 0..per {
                    let row: Vec<i32> = (0..784).map(|_| rng.below(128) as i32).collect();
                    h.infer_mlp(row).expect("mlp");
                }
            })
        })
        .collect();
    joins.into_iter().for_each(|j| j.join().unwrap());

    // CNN frames on top: the telemetry-bearing traffic.
    let mut live = LiveTelemetry::default();
    let input: Vec<i32> = (0..16 * 16 * 3).map(|v| (v % 251) - 125).collect();
    for _ in 0..(requests / 16).max(2) {
        let reply = h.infer_cnn(model.clone(), input.clone()).expect("cnn");
        if let Some(r) = &reply.report {
            live.add(r);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let served = per * clients + (requests / 16).max(2);

    let s = h.stats();
    let res = BackendResult {
        label: label.to_string(),
        req_per_s: served as f64 / wall,
        service_mean_us: s.service_mean() * 1e6,
        sim_fps: live.fps(),
        sim_fps_per_w: live.fps_per_w(),
    };
    assert_eq!(s.failed.load(Ordering::Relaxed), 0, "{label}: failures under load");
    c.shutdown();
    res
}

fn main() {
    let requests: usize =
        std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(256);
    let dir = synthetic_artifacts();
    let artifact_dir = dir.to_string_lossy().into_owned();
    let model = CnnModel {
        name: "edge_net",
        layers: vec![
            Layer::conv("stem", 16, 16, 3, 16, 3, 2, 1),
            Layer::dwconv("dw1", 8, 8, 16, 3, 1, 1),
            Layer::conv("pw1", 8, 8, 16, 32, 1, 1, 0),
            Layer::fc("head", 8 * 8 * 32, 10),
        ],
    };
    println!("coordinator backend matrix: {requests} MLP rows (8 clients) + CNN frames\n");

    let results: Vec<BackendResult> = [
        ("software", BackendKind::Software),
        ("photonic_spoga_10", BackendKind::Photonic(PhotonicConfig::spoga())),
        ("photonic_holylight_10", BackendKind::Photonic(PhotonicConfig::holylight())),
        ("photonic_deapcnn_10", BackendKind::Photonic(PhotonicConfig::deapcnn())),
    ]
    .into_iter()
    .map(|(label, kind)| bench_backend(label, kind, &artifact_dir, requests, &model))
    .collect();

    let mut t = Table::new(vec![
        "Backend",
        "req/s",
        "service µs",
        "sim FPS (CNN)",
        "sim FPS/W (CNN)",
    ]);
    for r in &results {
        t.row(vec![
            r.label.clone(),
            fmt_sig(r.req_per_s, 3),
            format!("{:.1}", r.service_mean_us),
            if r.sim_fps > 0.0 { fmt_sig(r.sim_fps, 3) } else { "-".into() },
            if r.sim_fps_per_w > 0.0 { fmt_sig(r.sim_fps_per_w, 3) } else { "-".into() },
        ]);
    }
    println!("{}", t.render());
    let overhead = results[0].req_per_s / results[1].req_per_s.max(1e-9);
    println!("telemetry overhead: software serves {overhead:.2}x the photonic-sim rate\n");

    // ---- JSON trajectory record ---------------------------------------------
    let out_path = std::env::var("BACKEND_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_backends.json".to_string());
    let rows: Vec<String> = results
        .iter()
        .map(|r| {
            format!(
                "    {{\"backend\": \"{}\", \"req_per_s\": {:.1}, \"service_mean_us\": {:.2}, \
                 \"sim_fps\": {:.1}, \"sim_fps_per_w\": {:.1}}}",
                r.label, r.req_per_s, r.service_mean_us, r.sim_fps, r.sim_fps_per_w
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"coordinator_backend_matrix\",\n  \"requests\": {requests},\n  \
         \"workload\": \"784-feature MLP rows (8 clients, dynamic batching) + edge_net CNN frames\",\n  \
         \"status\": \"measured\",\n  \"results\": [\n{}\n  ]\n}}\n",
        rows.join(",\n")
    );
    match std::fs::write(&out_path, &json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}
