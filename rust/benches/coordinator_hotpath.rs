//! Bench: the L3 serving hot path — coordinator overhead vs direct engine
//! execution, batching-window sweep, and worker scaling.
//!
//! This is the §Perf L3 bench (EXPERIMENTS.md): the coordinator should add
//! bounded overhead over raw PJRT dispatch, and dynamic batching should
//! beat per-request execution under concurrent load.
//!
//! Run: `make artifacts && cargo bench --bench coordinator_hotpath`

use std::time::Instant;

use spoga::benchkit::bench;
use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::report::{fmt_sig, Table};
use spoga::runtime::Engine;

fn main() {
    if !std::path::Path::new("artifacts/manifest.txt").exists() {
        println!("SKIP coordinator_hotpath: run `make artifacts` first");
        return;
    }

    // ---- baseline: direct engine, no coordinator ----------------------------
    let mut eng = Engine::new("artifacts").unwrap();
    eng.warmup("mlp_b1").unwrap();
    eng.warmup("mlp_b8").unwrap();
    eng.warmup("gemm_64x64x64").unwrap();
    let row = vec![5i32; 784];

    let direct_b1 = bench(2, 10, || eng.execute_i32_single("mlp_b1", &[&row]).unwrap());
    let batch8 = vec![5i32; 8 * 784];
    let direct_b8 = bench(2, 10, || eng.execute_i32_single("mlp_b8", &[&batch8]).unwrap());
    let a = vec![1i32; 64 * 64];
    let direct_gemm = bench(2, 20, || eng.execute_i32_single("gemm_64x64x64", &[&a, &a]).unwrap());

    let mut t = Table::new(vec!["Direct engine", "per call", "rows/s"]);
    t.row(vec![
        "mlp_b1".to_string(),
        format!("{:.2} ms", direct_b1.mean_s * 1e3),
        fmt_sig(direct_b1.per_second(), 3),
    ]);
    t.row(vec![
        "mlp_b8 (8 rows)".to_string(),
        format!("{:.2} ms", direct_b8.mean_s * 1e3),
        fmt_sig(8.0 * direct_b8.per_second(), 3),
    ]);
    t.row(vec![
        "gemm_64x64x64".to_string(),
        format!("{:.2} ms", direct_gemm.mean_s * 1e3),
        fmt_sig(direct_gemm.per_second(), 3),
    ]);
    println!("{}", t.render());
    println!(
        "batching amortization (direct): b8 gives {:.2}x rows/s over b1\n",
        8.0 * direct_b8.per_second() / direct_b1.per_second()
    );

    // ---- plan-cached packed-B: hit vs miss ----------------------------------
    // Ad-hoc GEMM plans keep the packed B operand cached per artifact,
    // revalidated by content equality. Repeated-B traffic takes the
    // cache-hit path (equality scan only); alternating-B traffic forces a
    // repack-in-place every call. The gap is the per-request packing cost
    // the plan cache removes from steady-state serving.
    let b1: Vec<i32> = (0..64 * 64).map(|v| ((v * 37) % 255) - 127).collect();
    let b2: Vec<i32> = b1.iter().map(|v| -v).collect();
    let hit = bench(2, 20, || eng.execute_reported("gemm_64x64x64", &[&a, &b1]).unwrap());
    let mut flip = false;
    let miss = bench(2, 20, || {
        flip = !flip;
        let b = if flip { &b1 } else { &b2 };
        eng.execute_reported("gemm_64x64x64", &[&a, b]).unwrap()
    });
    let mut t = Table::new(vec!["Packed-B plan cache", "per call", "calls/s"]);
    t.row(vec![
        "repeated B (cache hit)".to_string(),
        format!("{:.3} ms", hit.mean_s * 1e3),
        fmt_sig(hit.per_second(), 3),
    ]);
    t.row(vec![
        "alternating B (repack)".to_string(),
        format!("{:.3} ms", miss.mean_s * 1e3),
        fmt_sig(miss.per_second(), 3),
    ]);
    println!("{}", t.render());
    println!(
        "plan-cache effect: cache-hit serving is {:.2}x the repack path\n",
        hit.per_second() / miss.per_second()
    );

    // ---- coordinator under concurrent load ----------------------------------
    let mut t = Table::new(vec![
        "Coordinator config",
        "req/s",
        "mean lat ms",
        "p99 ms",
        "occupancy",
    ]);
    for (workers, window_ms, clients, requests) in
        [(1usize, 0.0f64, 1usize, 48usize), (1, 3.0, 8, 96), (2, 3.0, 8, 96), (2, 8.0, 16, 128)]
    {
        let c = Coordinator::start(CoordinatorConfig {
            workers,
            max_batch_wait_s: window_ms * 1e-3,
            ..Default::default()
        })
        .unwrap();
        let h = c.handle();
        // Warm the pipeline (workers compile lazily on their own threads).
        h.infer_mlp(vec![0; 784]).unwrap();

        let t0 = Instant::now();
        let per = requests / clients;
        let joins: Vec<_> = (0..clients)
            .map(|cl| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..per {
                        h.infer_mlp(vec![((cl + i) % 100) as i32; 784]).unwrap();
                    }
                })
            })
            .collect();
        joins.into_iter().for_each(|j| j.join().unwrap());
        let dt = t0.elapsed().as_secs_f64();
        let s = h.stats();
        t.row(vec![
            format!("{workers}w / {window_ms}ms window / {clients} clients"),
            fmt_sig((per * clients) as f64 / dt, 3),
            format!("{:.1}", s.latency_mean() * 1e3),
            format!("{:.1}", s.latency_percentile(0.99) * 1e3),
            format!("{:.2}", s.mean_batch_occupancy()),
        ]);
        c.shutdown();
    }
    println!("coordinator hot path:\n{}", t.render());
}
