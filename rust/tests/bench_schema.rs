//! Schema guard over the committed `BENCH_*.json` trajectory snapshots.
//!
//! The bench binaries hand-format their JSON records (no serde in the
//! offline dependency set) and the numbers are filled in on a toolchain
//! host, so a drifting emitter or a hand-edit slip would otherwise be
//! discovered only there. Parsing the committed snapshots in tier-1 — with
//! the in-tree [`spoga::testing::Json`] parser — turns schema drift into a
//! test failure instead.

use spoga::testing::Json;

/// Load and parse a snapshot committed at the repository root.
fn load(name: &str) -> Json {
    let path = format!("{}/../{name}", env!("CARGO_MANIFEST_DIR"));
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{path}: snapshot must exist and be readable: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("{name}: invalid JSON: {e}"))
}

/// Assert the snapshot's shared shape: a `bench` name, a known `status`,
/// and a non-empty `results` array whose rows all carry `row_keys`, each
/// either `null` (pending) or the expected scalar kind. Returns the rows
/// parsed as objects for file-specific checks.
fn check_schema(name: &str, bench: &str, row_keys: &[(&str, Kind)]) -> Vec<Json> {
    let doc = load(name);
    assert_eq!(
        doc.get("bench").and_then(Json::as_str),
        Some(bench),
        "{name}: bench field must name its emitter"
    );
    let status = doc.get("status").and_then(Json::as_str).unwrap_or_default().to_string();
    assert!(
        status == "pending-first-run" || status == "measured",
        "{name}: unknown status {status:?}"
    );
    let rows = doc
        .get("results")
        .and_then(Json::as_arr)
        .unwrap_or_else(|| panic!("{name}: results must be an array"));
    assert!(!rows.is_empty(), "{name}: results must be non-empty");
    for (i, row) in rows.iter().enumerate() {
        for (key, kind) in row_keys {
            let v = row
                .get(key)
                .unwrap_or_else(|| panic!("{name}: results[{i}] missing key {key:?}"));
            let ok = match kind {
                Kind::Label => v.as_str().is_some(),
                // Metric cells are null until a toolchain host fills them.
                Kind::Metric => v.is_null() || v.as_num().is_some(),
                Kind::Number => v.as_num().is_some(),
            };
            assert!(ok, "{name}: results[{i}].{key} has wrong kind: {v:?}");
            if status == "measured" && matches!(kind, Kind::Metric) {
                assert!(
                    v.as_num().is_some(),
                    "{name}: measured snapshot still has null {key:?} in results[{i}]"
                );
            }
        }
    }
    rows.to_vec()
}

/// Expected kind of a result cell.
enum Kind {
    /// Always a string (row label).
    Label,
    /// Always a number (grid coordinates committed with the schema).
    Number,
    /// Number once measured, `null` while `status: pending-first-run`.
    Metric,
}

#[test]
fn bitslice_snapshot_keeps_schema() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_bitslice.json",
        "bitslice_throughput",
        &[
            ("dim", Number),
            ("naive_gops", Metric),
            ("packed_gops", Metric),
            ("packed_mt_gops", Metric),
            // Prepacked-B serving rows (pack-once/stream-many): scalar
            // micro-kernel vs the SIMD default, B packed outside the timer.
            ("packed_planned_gops", Metric),
            ("packed_planned_simd_gops", Metric),
            ("speedup_mt_vs_naive", Metric),
        ],
    );
    let dims: Vec<f64> = rows.iter().map(|r| r.get("dim").unwrap().as_num().unwrap()).collect();
    assert_eq!(dims, vec![64.0, 256.0, 1024.0]);
}

#[test]
fn cnn_snapshot_keeps_schema_and_grid() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_cnn.json",
        "cnn_hotpath",
        &[
            ("path", Label),
            ("micro", Label),
            ("batch", Number),
            ("frames_per_s", Metric),
            ("speedup_vs_legacy", Metric),
        ],
    );
    // The committed grid: both paths at scalar and simd across the batch
    // sweep must be present. A measured snapshot may append avx2 rows when
    // the recording host detects the feature; the schema check above
    // already covered them.
    for micro in ["scalar", "simd"] {
        for path in ["legacy", "plan"] {
            for batch in [1.0, 4.0, 16.0] {
                assert!(
                    rows.iter().any(|r| r.get("path").unwrap().as_str() == Some(path)
                        && r.get("micro").unwrap().as_str() == Some(micro)
                        && r.get("batch").unwrap().as_num() == Some(batch)),
                    "BENCH_cnn.json missing ({path}, {micro}, batch {batch}) row"
                );
            }
        }
    }
}

#[test]
fn backends_snapshot_keeps_schema() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_backends.json",
        "coordinator_backend_matrix",
        &[
            ("backend", Label),
            ("req_per_s", Metric),
            ("service_mean_us", Metric),
            ("sim_fps", Metric),
            ("sim_fps_per_w", Metric),
        ],
    );
    assert!(rows
        .iter()
        .any(|r| r.get("backend").unwrap().as_str() == Some("software")));
}

#[test]
fn fleet_snapshot_keeps_schema() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_fleet.json",
        "fleet_scaling",
        &[
            ("fleet", Label),
            ("shards", Number),
            ("req_per_s", Metric),
            ("p99_us", Metric),
            ("cnn_batches", Metric),
        ],
    );
    assert!(rows.len() >= 4, "fleet snapshot must cover the 1/2/4-shard + A/B rows");
}

#[test]
fn resilience_snapshot_keeps_schema() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_resilience.json",
        "resilience",
        &[
            ("scenario", Label),
            ("requests", Number),
            ("req_per_s", Metric),
            ("resubmits", Metric),
            ("recovery_ms", Metric),
            ("p99_us", Metric),
            ("shed", Metric),
        ],
    );
    // The scenarios the bench emits, in order: healthy baseline, mid-flight
    // failover, revival timing, then the QoS overload pair (High held vs
    // BestEffort shedding at the admission watermark).
    let scenarios: Vec<&str> =
        rows.iter().map(|r| r.get("scenario").unwrap().as_str().unwrap()).collect();
    assert_eq!(
        scenarios,
        vec![
            "baseline",
            "mid_flight_failover",
            "revival",
            "overload_high",
            "overload_best_effort"
        ]
    );
}

#[test]
fn net_snapshot_keeps_schema() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_net.json",
        "net_roundtrip",
        &[
            ("path", Label),
            ("requests", Number),
            ("req_per_s", Metric),
            ("mean_us", Metric),
            ("overhead_us", Metric),
        ],
    );
    // Two fixed rows, in emitter order: the transport-free baseline, then
    // the loopback shard-server path whose overhead_us is the headline.
    let paths: Vec<&str> =
        rows.iter().map(|r| r.get("path").unwrap().as_str().unwrap()).collect();
    assert_eq!(paths, vec!["in_process", "loopback_tcp"]);
}

#[test]
fn noise_snapshot_keeps_schema_and_grid() {
    use Kind::*;
    let rows = check_schema(
        "BENCH_noise.json",
        "noise_frontier",
        &[
            ("k", Number),
            ("adc_bits", Number),
            ("req_per_s", Metric),
            ("served_exact", Metric),
            ("noise_events", Metric),
            ("lanes", Metric),
            ("sim_fps", Metric),
            ("sim_fps_per_w", Metric),
        ],
    );
    // The committed grid must stay in step with the bench's default
    // (`NoiseSweepGrid::paper_range()`), cells in K-major shard order.
    let grid = spoga::coordinator::NoiseSweepGrid::paper_range();
    let expect: Vec<(f64, f64)> =
        grid.cells().into_iter().map(|(k, b)| (k as f64, b as f64)).collect();
    let got: Vec<(f64, f64)> = rows
        .iter()
        .map(|r| {
            (
                r.get("k").unwrap().as_num().unwrap(),
                r.get("adc_bits").unwrap().as_num().unwrap(),
            )
        })
        .collect();
    assert_eq!(got, expect, "BENCH_noise.json rows drifted from the paper-range grid");
}
