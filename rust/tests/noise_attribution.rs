//! Seeded-determinism suite for per-row noise attribution.
//!
//! Pins the PR's acceptance contract (see `runtime/backend.rs`'s per-row
//! contract docs), all against synthetic manifests so nothing ever skips:
//!
//! * a stacked CNN batch's per-frame noise events, per-row attribution and
//!   logits are **bit-identical** to the same frames served unbatched at
//!   the same noise seed — batching never changes what a request observes;
//! * `sum(row_noise) == noise_events` across random GEMM shapes, including
//!   zero-row and non-tile-multiple cases, with `row_noise[r]` equal to the
//!   actual per-row divergence count against the exact backend;
//! * the coordinator keeps CNN stacking and full MLP batching enabled
//!   under noise, and every reply carries its own row/frame attribution.

use std::cell::RefCell;
use std::path::PathBuf;

use spoga::coordinator::{Coordinator, CoordinatorConfig, Response};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::fidelity::NoiseParams;
use spoga::runtime::cnnrun::{run_cnn, run_cnn_batch};
use spoga::runtime::{BackendKind, Engine, PhotonicConfig};
use spoga::testing::{forall, SplitMix64};

const MANIFEST: &str = "\
gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8
gemm_0x8x4 g0.hlo.txt i32:0x8,i32:8x4 i32:0x4
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spoga-noise-attr-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

/// A loud noisy SPOGA backend (0 dB margin unless overridden) with a fixed
/// deterministic stream seed.
fn noisy_kind(margin_db: f64, seed: u64) -> BackendKind {
    BackendKind::Photonic(
        PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(margin_db), seed),
    )
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "tiny_attr",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::dwconv("dw", 6, 6, 4, 3, 2, 1),
            Layer::fc("head", 3 * 3 * 4, 5),
        ],
    }
}

fn frames(n: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|f| (0..6 * 6 * 3).map(|v| ((v * 31 + f * 97) % 251) - 125).collect())
        .collect()
}

#[test]
fn stacked_cnn_frames_attribute_noise_identically_to_unbatched() {
    let dir = synthetic_dir("stacked");
    let kind = noisy_kind(0.0, 0xA77B_17);
    let frames = frames(3);
    let refs: Vec<&[i32]> = frames.iter().map(|f| f.as_slice()).collect();

    let mut stacked_eng = Engine::with_backend(&dir, kind.clone()).unwrap();
    let batched = run_cnn_batch(&mut stacked_eng, &tiny_cnn(), &refs).unwrap();
    assert_eq!(batched.len(), frames.len());

    let mut total_noise = 0u64;
    for (f, frame) in frames.iter().enumerate() {
        // Fresh engine per unbatched run: nothing carries over but the seed.
        let mut single_eng = Engine::with_backend(&dir, kind.clone()).unwrap();
        let single = run_cnn(&mut single_eng, &tiny_cnn(), frame).unwrap();

        assert_eq!(
            batched[f].logits, single.logits,
            "frame {f}: stacked logits diverged from unbatched at the same seed"
        );
        assert_eq!(batched[f].layers.len(), single.layers.len());
        for (bl, sl) in batched[f].layers.iter().zip(&single.layers) {
            assert_eq!(bl.layer, sl.layer);
            // PartialEq covers latency/energy/lanes AND noise_events AND
            // the per-row attribution vector.
            assert_eq!(
                bl.report, sl.report,
                "frame {f} layer {}: stacked attribution diverged",
                bl.layer
            );
            assert_eq!(
                bl.report.row_noise.iter().sum::<u64>(),
                bl.report.noise_events,
                "frame {f} layer {}: row attribution must sum to the scalar",
                bl.layer
            );
        }
        let (ba, sa) = (batched[f].report.as_ref(), single.report.as_ref());
        assert_eq!(ba, sa, "frame {f}: aggregate reports diverged");
        total_noise += ba.unwrap().noise_events;
    }
    // Sanity that the property bites: 0 dB margin must actually perturb.
    assert!(total_noise > 0, "loud channel produced no noise events");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn same_engine_serves_stacked_then_unbatched_identically() {
    // Content-keyed sub-streams leave no serving-order state behind: one
    // engine can serve the stack and then each frame alone and still agree.
    let dir = synthetic_dir("stateless");
    let frames = frames(2);
    let refs: Vec<&[i32]> = frames.iter().map(|f| f.as_slice()).collect();
    let mut eng = Engine::with_backend(&dir, noisy_kind(0.0, 7)).unwrap();
    let batched = run_cnn_batch(&mut eng, &tiny_cnn(), &refs).unwrap();
    for (f, frame) in frames.iter().enumerate() {
        let single = run_cnn(&mut eng, &tiny_cnn(), frame).unwrap();
        assert_eq!(batched[f].logits, single.logits, "frame {f}");
        assert_eq!(batched[f].report, single.report, "frame {f}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn row_noise_sums_to_noise_events_across_random_shapes() {
    let dir = synthetic_dir("prop");
    // One engine pair reused across cases (plan caches grow per shape);
    // RefCell because the property closure is Fn.
    let noisy = RefCell::new(Engine::with_backend(&dir, noisy_kind(0.0, 99)).unwrap());
    let exact = RefCell::new(
        Engine::with_backend(&dir, BackendKind::Photonic(PhotonicConfig::spoga())).unwrap(),
    );

    // Shapes straddle the packed-kernel dispatch threshold and include
    // non-tile-multiple dims (the tiled kernels block by kc/jc).
    let gen = |rng: &mut SplitMix64| {
        let m = rng.range_usize(1, 33);
        let k = rng.range_usize(1, 70);
        let n = rng.range_usize(1, 18);
        let a: Vec<i32> = (0..m * k).map(|_| rng.i8() as i32).collect();
        let b: Vec<i32> = (0..k * n).map(|_| rng.i8() as i32).collect();
        (m, k, n, a, b)
    };
    forall(0x5EED_0401, 25, gen, |(m, k, n, a, b)| {
        let (out, rep) =
            noisy.borrow_mut().execute_gemm_shape(*m, *k, *n, a, b).expect("noisy gemm");
        let rep = rep.expect("photonic telemetry");
        let (gold, _) =
            exact.borrow_mut().execute_gemm_shape(*m, *k, *n, a, b).expect("exact gemm");
        if rep.row_noise.len() != *m
            || rep.row_noise.iter().sum::<u64>() != rep.noise_events
            || rep.lanes != (*m * *n) as u64
        {
            return false;
        }
        // row_noise[r] is exactly the number of divergent outputs in row r.
        (0..*m).all(|r| {
            let mism =
                (0..*n).filter(|&j| out[r * n + j] != gold[r * n + j]).count() as u64;
            rep.row_noise[r] == mism
        })
    });
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn zero_row_and_zero_content_rows_attribute_cleanly() {
    let dir = synthetic_dir("zero");
    let mut eng = Engine::with_backend(&dir, noisy_kind(0.0, 3)).unwrap();

    // Zero-row GEMM (manifest artifact — ad-hoc shapes reject m == 0):
    // empty outputs, empty attribution, zero events, no panic.
    let b: Vec<i32> = (0..8 * 4).map(|v| (v % 200) - 100).collect();
    let (out, rep) = eng.execute_reported("gemm_0x8x4", &[&[], &b]).unwrap();
    let rep = rep.expect("photonic telemetry");
    assert!(out.is_empty());
    assert!(rep.row_noise.is_empty());
    assert_eq!((rep.noise_events, rep.lanes), (0, 0));

    // All-zero content rows still get one attribution slot each and keep
    // the sum identity (noise can perturb a zero row into nonzero output).
    let zeros = vec![0i32; 3 * 16];
    let w: Vec<i32> = (0..16 * 4).map(|v| (v % 251) - 125).collect();
    let (zout, zrep) = eng.execute_gemm_shape(3, 16, 4, &zeros, &w).unwrap();
    let zrep = zrep.unwrap();
    assert_eq!(zout.len(), 12);
    assert_eq!(zrep.row_noise.len(), 3);
    assert_eq!(zrep.row_noise.iter().sum::<u64>(), zrep.noise_events);
    // Identical zero rows draw identical content-keyed noise.
    assert_eq!(zout[0..4], zout[4..8]);
    assert_eq!(zrep.row_noise[0], zrep.row_noise[1]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn coordinator_keeps_batching_on_under_noise_with_exact_replies() {
    let dir = synthetic_dir("coord");
    let kind = noisy_kind(0.0, 0xC00D_1E55);
    let c = Coordinator::start(CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        backend: kind.clone(),
        max_batch_wait_s: 0.01,
        ..Default::default()
    })
    .unwrap();
    let h = c.handle();

    // CNN frames submitted back to back stack in the window — under noise.
    let model = tiny_cnn();
    let inputs = frames(3);
    let slots: Vec<Response> = inputs
        .iter()
        .map(|input| h.submit_cnn(model.clone(), input.clone()).unwrap())
        .collect();
    let replies: Vec<_> = slots
        .into_iter()
        .map(|rx| rx.recv().unwrap().expect("noisy cnn frame served"))
        .collect();
    assert!(
        h.stats().cnn_batches.load(std::sync::atomic::Ordering::Relaxed) >= 1,
        "CNN stacking must stay enabled under noise injection"
    );

    // Every reply is bit-identical to an engine-level unbatched run at the
    // same seed — whatever stacking the leader's window happened to form.
    for (f, input) in inputs.iter().enumerate() {
        let mut eng = Engine::with_backend(&dir, kind.clone()).unwrap();
        let single = run_cnn(&mut eng, &model, input).unwrap();
        assert_eq!(replies[f].outputs, single.logits, "frame {f} logits");
        assert_eq!(replies[f].report, single.report, "frame {f} report");
        assert_eq!(replies[f].layers.len(), single.layers.len());
        for (served, expect) in replies[f].layers.iter().zip(&single.layers) {
            assert_eq!(served.report, expect.report, "frame {f} layer {}", served.layer);
        }
    }

    // MLP rows batch at full variants under noise, each reply carrying its
    // own row's attribution — and identical rows observe identical noise
    // regardless of batch membership.
    let noise_before = h.stats().noise_events.load(std::sync::atomic::Ordering::Relaxed);
    let lanes_before = h.stats().lanes.load(std::sync::atomic::Ordering::Relaxed);
    let row: Vec<i32> = (0..16).map(|v| (v * 7) % 100).collect();
    let mlp_slots: Vec<Response> =
        (0..4).map(|_| h.submit_mlp(row.clone()).unwrap()).collect();
    let mlp_replies: Vec<_> = mlp_slots
        .into_iter()
        .map(|rx| rx.recv().unwrap().expect("noisy mlp row served"))
        .collect();
    // Stats count exactly what the replies carried: zero-padding rows'
    // noise never leaks into the shard's served-exact accounting, however
    // the batching window happened to split the four rows.
    let noise_delta =
        h.stats().noise_events.load(std::sync::atomic::Ordering::Relaxed) - noise_before;
    let lanes_delta = h.stats().lanes.load(std::sync::atomic::Ordering::Relaxed) - lanes_before;
    let reply_noise: u64 = mlp_replies
        .iter()
        .map(|r| r.report.as_ref().unwrap().noise_events)
        .sum();
    assert_eq!(noise_delta, reply_noise, "padding noise leaked into stats");
    assert_eq!(lanes_delta, 4 * 4, "stats lanes must cover exactly the served rows");
    for reply in &mlp_replies {
        let rep = reply.report.as_ref().expect("photonic telemetry");
        assert_eq!(rep.lanes, 4, "member lanes are its own row's outputs");
        assert_eq!(rep.row_noise.len(), 1, "member attribution is one row");
        assert_eq!(rep.row_noise[0], rep.noise_events);
        assert_eq!(reply.outputs, mlp_replies[0].outputs, "identical rows, identical noise");
        assert_eq!(
            rep.noise_events,
            mlp_replies[0].report.as_ref().unwrap().noise_events
        );
    }

    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nonce_mode_decorrelates_duplicate_requests_and_stays_deterministic() {
    // The time-indexed counter mode (`CoordinatorConfig::noise_nonce`):
    // byte-identical requests served under different per-request nonces
    // observe *different* noise — fixing the perfect correlation the pure
    // content-keyed path accepts as the price of order independence —
    // while a fresh coordinator replaying the same submission order
    // reproduces every output bit for bit.
    let dir = synthetic_dir("nonce");
    let kind = noisy_kind(0.0, 0xD0_C0_FFEE);
    let cfg = CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        backend: kind.clone(),
        max_batch_wait_s: 0.01,
        noise_nonce: true,
        ..Default::default()
    };
    let row: Vec<i32> = (0..16).map(|v| (v * 11) % 90).collect();

    let serve_pair = |cfg: CoordinatorConfig| {
        let c = Coordinator::start(cfg).unwrap();
        let h = c.handle();
        // Slot-based back-to-back submissions so the pair co-batches —
        // decorrelation must hold *inside* one stacked execute.
        let slots: Vec<Response> =
            (0..2).map(|_| h.submit_mlp(row.clone()).unwrap()).collect();
        let outs: Vec<Vec<i32>> = slots
            .into_iter()
            .map(|rx| rx.recv().unwrap().expect("nonced mlp served").outputs)
            .collect();
        c.shutdown();
        outs
    };

    let first = serve_pair(cfg.clone());
    assert_ne!(
        first[0], first[1],
        "duplicate rows under distinct nonces must observe decorrelated noise"
    );
    // Per-request determinism: a fresh coordinator at the same seed serving
    // the same submission order reproduces both outputs exactly.
    let again = serve_pair(cfg.clone());
    assert_eq!(first, again, "counter-mode noise must replay deterministically");

    // Default-off control: the same traffic with the nonce mode disabled
    // keeps the historical perfectly-correlated content-keyed behavior.
    let plain = serve_pair(CoordinatorConfig { noise_nonce: false, ..cfg });
    assert_eq!(plain[0], plain[1], "content keying must correlate identical rows");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nonced_cnn_stacks_decorrelate_duplicate_frames_per_frame() {
    // Engine-level: run_cnn_batch_keyed with per-frame nonces — duplicate
    // frames in one stack decorrelate, equal nonces reproduce, empty
    // nonces stay bit-identical to the unkeyed path.
    use spoga::runtime::run_cnn_batch_keyed;
    let dir = synthetic_dir("noncecnn");
    let kind = noisy_kind(0.0, 0x0FF_BEEF);
    let model = tiny_cnn();
    let frame = frames(1).pop().unwrap();
    let refs: Vec<&[i32]> = vec![&frame, &frame];

    let mut eng = Engine::with_backend(&dir, kind.clone()).unwrap();
    let plain = run_cnn_batch(&mut eng, &model, &refs).unwrap();
    assert_eq!(
        plain[0].logits, plain[1].logits,
        "content keying must correlate duplicate frames"
    );
    let keyed_empty = run_cnn_batch_keyed(&mut eng, &model, &refs, &[]).unwrap();
    assert_eq!(keyed_empty[0].logits, plain[0].logits, "empty nonces == unkeyed path");

    let nonced = run_cnn_batch_keyed(&mut eng, &model, &refs, &[1, 2]).unwrap();
    assert_ne!(
        nonced[0].logits, nonced[1].logits,
        "distinct frame nonces must decorrelate duplicate frames"
    );
    // Determinism and the per-frame attribution contract survive keying.
    let again = run_cnn_batch_keyed(&mut eng, &model, &refs, &[1, 2]).unwrap();
    for f in 0..2 {
        assert_eq!(nonced[f].logits, again[f].logits, "frame {f} replay");
        let rep = nonced[f].report.as_ref().expect("noisy telemetry");
        assert_eq!(
            rep.row_noise.iter().sum::<u64>(),
            rep.noise_events,
            "frame {f} sum(row_noise) == noise_events under nonces"
        );
    }
    // A frame keyed by the same nonce alone reproduces its stacked self:
    // nonces key content, not batch position.
    let alone = run_cnn_batch_keyed(&mut eng, &model, &[&frame], &[2]).unwrap();
    assert_eq!(alone[0].logits, nonced[1].logits, "nonce keying is position-independent");

    let _ = std::fs::remove_dir_all(&dir);
}
