//! Pack-once / stream-many acceptance suite.
//!
//! Pins the PR's bit-exactness contract (see `bitslice/mod.rs` "Prepacked
//! API" and `runtime/backend.rs` "Plan-owns-packed-weights contract"):
//!
//! * every `gemm_*_prepacked` entry point is **bit-identical** to the
//!   repack-per-call dispatcher and to the `*_naive` oracle across random
//!   non-tile-multiple shapes, ±extreme operands, and zero-row/zero-col
//!   artifacts — for both the Simd and Scalar micro-kernels;
//! * streaming many activations against one packed B never corrupts the
//!   packed operand (each call agrees with a fresh pack);
//! * a plan-cached photonic shard **under noise** serves bit-identically
//!   to a fresh engine at the same seed: content-keyed noise is a pure
//!   function of the lane charges, which prepacking preserves, so the
//!   packing-placement change must be invisible end to end — including
//!   across B-cache hits, refreshes, and interleaved artifacts.

use spoga::bitslice::{
    gemm_i16_lanes, gemm_i16_lanes_naive, gemm_i16_lanes_prepacked, gemm_i32, gemm_i32_naive,
    gemm_i32_prepacked, gemm_i32_tiled, gemm_lanes, gemm_lanes_naive, gemm_lanes_packed,
    gemm_lanes_prepacked, gemm_sliced, gemm_sliced_naive, gemm_sliced_prepacked, pack_b,
    MicroKernel, NibblePlanes, PackedB, TileConfig, WidePlanes,
};
use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::fidelity::NoiseParams;
use spoga::runtime::{BackendKind, Engine, PhotonicConfig};
use spoga::testing::prop::GemmCase;
use spoga::testing::forall;

// ---------------------------------------------------------------------------
// kernel-level bit-exactness
// ---------------------------------------------------------------------------

/// Prepacked entry points agree with the repack-per-call dispatchers and the
/// naive oracles on random shapes. `max_dim: 14` keeps every case below the
/// packed-dispatch threshold, so the dispatchers run *naive* while the
/// prepacked lane/sliced paths always run the packed kernel — the strongest
/// cross-check available (two independent implementations per case).
#[test]
fn prop_prepacked_bit_exact_vs_dispatch_and_naive() {
    forall(0x9EED_0701, 60, GemmCase { max_dim: 14 }, |(a, b, m, k, n)| {
        let pb = pack_b(b, *k, *n).unwrap();
        let pa = NibblePlanes::pack(a, *m, *k).unwrap();

        let direct = gemm_i32_prepacked(a, &pb, *m).unwrap();
        if direct != gemm_i32(a, b, *m, *k, *n).unwrap()
            || direct != gemm_i32_naive(a, b, *m, *k, *n).unwrap()
        {
            return false;
        }

        let lanes = gemm_lanes_prepacked(&pa, pb.planes()).unwrap();
        let lanes_ref = gemm_lanes_naive(a, b, *m, *k, *n).unwrap();
        if lanes.hi != lanes_ref.hi || lanes.mid != lanes_ref.mid || lanes.lo != lanes_ref.lo {
            return false;
        }
        if lanes.weight_and_add() != gemm_lanes(a, b, *m, *k, *n).unwrap().weight_and_add() {
            return false;
        }

        let sl = gemm_sliced_prepacked(&pa, pb.planes()).unwrap();
        let sl_ref = gemm_sliced_naive(a, b, *m, *k, *n).unwrap();
        sl.mm == sl_ref.mm
            && sl.ml == sl_ref.ml
            && sl.lm == sl_ref.lm
            && sl.ll == sl_ref.ll
            && sl.recombine() == gemm_sliced(a, b, *m, *k, *n).unwrap().recombine()
    });
}

/// A shape above the packed-dispatch threshold: here the dispatcher runs
/// the tiled kernel too, so this pins prepacked == tiled == naive at scale
/// (40³ MACs clears both dispatch gates).
#[test]
fn prepacked_matches_tiled_dispatch_above_threshold() {
    let (m, k, n) = (40, 40, 40);
    let a: Vec<i8> = (0..m * k).map(|v| ((v * 37 + 11) % 256) as u8 as i8).collect();
    let b: Vec<i8> = (0..k * n).map(|v| ((v * 73 + 5) % 256) as u8 as i8).collect();
    let pb = pack_b(&b, k, n).unwrap();
    let pa = NibblePlanes::pack(&a, m, k).unwrap();

    let naive = gemm_i32_naive(&a, &b, m, k, n).unwrap();
    assert_eq!(gemm_i32_prepacked(&a, &pb, m).unwrap(), naive);
    assert_eq!(gemm_i32(&a, &b, m, k, n).unwrap(), naive);
    assert_eq!(
        gemm_lanes_prepacked(&pa, pb.planes()).unwrap().weight_and_add(),
        naive
    );
    assert_eq!(gemm_sliced_prepacked(&pa, pb.planes()).unwrap().recombine(), naive);
}

/// ±extreme operands (full i8 corners incl. -128) through both micro-kernels
/// at a width exercising two full SIMD blocks plus a 7-wide scalar tail.
#[test]
fn extreme_operands_bit_exact_across_micro_kernels() {
    let (m, k, n) = (5, 19, 23);
    let corners: [i8; 7] = [-128, 127, 0, -1, 1, 64, -64];
    let a: Vec<i8> = (0..m * k).map(|v| corners[v % corners.len()]).collect();
    let b: Vec<i8> = (0..k * n).map(|v| corners[(v * 3 + 1) % corners.len()]).collect();
    let pb = pack_b(&b, k, n).unwrap();
    let pa = NibblePlanes::pack(&a, m, k).unwrap();
    let naive = gemm_i32_naive(&a, &b, m, k, n).unwrap();
    let lanes_ref = gemm_lanes_naive(&a, &b, m, k, n).unwrap();

    for micro in [MicroKernel::Simd, MicroKernel::Scalar] {
        let cfg = TileConfig { kc: 7, jc: 9, threads: 2, micro };
        assert_eq!(
            gemm_i32_tiled(&a, pb.raw(), m, k, n, &cfg).unwrap(),
            naive,
            "direct kernel diverged under {micro:?}"
        );
        let lanes = gemm_lanes_packed(&pa, pb.planes(), &cfg).unwrap();
        assert_eq!(
            (lanes.hi, lanes.mid, lanes.lo),
            (lanes_ref.hi.clone(), lanes_ref.mid.clone(), lanes_ref.lo.clone()),
            "lane kernel diverged under {micro:?}"
        );
    }
    // The auto-config prepacked entry points agree with the same oracles.
    assert_eq!(gemm_i32_prepacked(&a, &pb, m).unwrap(), naive);
    assert_eq!(gemm_lanes_prepacked(&pa, pb.planes()).unwrap().weight_and_add(), naive);
}

/// Zero-row and zero-col operands (the `gemm_0x8x4`-style artifacts) pass
/// cleanly through every prepacked path: empty outputs, no panic. The lane
/// path matters most — prepacked serving runs the packed kernel even for
/// shapes the dispatcher would have routed to naive.
#[test]
fn zero_row_and_zero_col_prepacked() {
    // m == 0: empty A against a real packed B.
    let b: Vec<i8> = (0..8 * 4).map(|v| (v as i8).wrapping_mul(9)).collect();
    let pb = pack_b(&b, 8, 4).unwrap();
    let pa0 = NibblePlanes::pack(&[], 0, 8).unwrap();
    assert!(gemm_i32_prepacked(&[], &pb, 0).unwrap().is_empty());
    let lanes = gemm_lanes_prepacked(&pa0, pb.planes()).unwrap();
    assert!(lanes.hi.is_empty() && lanes.mid.is_empty() && lanes.lo.is_empty());
    assert!(gemm_sliced_prepacked(&pa0, pb.planes()).unwrap().mm.is_empty());

    // n == 0: real A against an empty-column packed B.
    let a: Vec<i8> = (0..2 * 8).map(|v| (v as i8).wrapping_sub(7)).collect();
    let pb0 = pack_b(&[], 8, 0).unwrap();
    let pa = NibblePlanes::pack(&a, 2, 8).unwrap();
    assert!(gemm_i32_prepacked(&a, &pb0, 2).unwrap().is_empty());
    assert!(gemm_lanes_prepacked(&pa, pb0.planes()).unwrap().hi.is_empty());
}

/// INT16 wide prepacked path agrees with the dispatcher and the naive
/// oracle, including i16 corners.
#[test]
fn wide_prepacked_bit_exact() {
    let (m, k, n) = (3, 11, 10);
    let corners: [i16; 6] = [i16::MIN, i16::MAX, 0, -1, 256, -4096];
    let a: Vec<i16> = (0..m * k).map(|v| corners[v % corners.len()]).collect();
    let b: Vec<i16> = (0..k * n).map(|v| corners[(v * 5 + 2) % corners.len()]).collect();
    let pa = WidePlanes::pack(&a, m, k).unwrap();
    let pb = WidePlanes::pack(&b, k, n).unwrap();

    let got = gemm_i16_lanes_prepacked(&pa, &pb).unwrap().weight_and_add();
    assert_eq!(got, gemm_i16_lanes(&a, &b, m, k, n).unwrap().weight_and_add());
    assert_eq!(got, gemm_i16_lanes_naive(&a, &b, m, k, n).unwrap().weight_and_add());
}

/// Stream-many: one packed B serves a stream of activations; every answer
/// matches a fresh pack-per-call run, and the packed operand is bitwise
/// unchanged afterwards.
#[test]
fn streaming_reuses_packed_b_without_corruption() {
    let (m, k, n) = (4, 12, 9);
    let b: Vec<i8> = (0..k * n).map(|v| ((v * 29 + 3) % 256) as u8 as i8).collect();
    let pb = pack_b(&b, k, n).unwrap();
    let raw_before = pb.raw().to_vec();

    for step in 0..10 {
        let a: Vec<i8> =
            (0..m * k).map(|v| ((v * 13 + step * 41) % 256) as u8 as i8).collect();
        let fresh = pack_b(&b, k, n).unwrap();
        assert_eq!(
            gemm_i32_prepacked(&a, &pb, m).unwrap(),
            gemm_i32_prepacked(&a, &fresh, m).unwrap(),
            "stream step {step} diverged from a fresh pack"
        );
        assert_eq!(
            gemm_i32_prepacked(&a, &pb, m).unwrap(),
            gemm_i32_naive(&a, &b, m, k, n).unwrap(),
            "stream step {step} diverged from naive"
        );
    }
    assert_eq!(pb.raw(), &raw_before[..], "streaming mutated the packed operand");
    let wire: Vec<i32> = b.iter().map(|&v| v as i32).collect();
    assert!(pb.matches_wire(&wire), "content identity lost after streaming");
}

/// `refresh_wire` reuse is content-exact: after a hit the packed B computes
/// the same answers as a from-scratch pack; after a miss it computes the
/// *new* B's answers (no stale plane data survives the in-place repack).
#[test]
fn refresh_wire_preserves_and_replaces_content_exactly() {
    let (m, k, n) = (3, 10, 8);
    let a: Vec<i8> = (0..m * k).map(|v| (v as i8).wrapping_mul(17)).collect();
    let b1: Vec<i32> = (0..k * n).map(|v| ((v * 7) % 200) as i32 - 100).collect();
    let b2: Vec<i32> = b1.iter().map(|v| -v).collect();
    let b1_i8: Vec<i8> = b1.iter().map(|&v| v as i8).collect();
    let b2_i8: Vec<i8> = b2.iter().map(|&v| v as i8).collect();

    let first = PackedB::refresh_wire(None, &b1, k, n).unwrap();
    let hit = PackedB::refresh_wire(Some(first), &b1, k, n).unwrap();
    assert_eq!(
        gemm_i32_prepacked(&a, &hit, m).unwrap(),
        gemm_i32_naive(&a, &b1_i8, m, k, n).unwrap()
    );
    let miss = PackedB::refresh_wire(Some(hit), &b2, k, n).unwrap();
    assert_eq!(
        gemm_i32_prepacked(&a, &miss, m).unwrap(),
        gemm_i32_naive(&a, &b2_i8, m, k, n).unwrap(),
        "repacked-in-place B must compute the new operand's results"
    );
    let lanes = gemm_lanes_prepacked(
        &NibblePlanes::pack(&a, m, k).unwrap(),
        miss.planes(),
    )
    .unwrap();
    assert_eq!(
        lanes.weight_and_add(),
        gemm_lanes_naive(&a, &b2_i8, m, k, n).unwrap().weight_and_add(),
        "stale nibble planes survived the refresh"
    );
}

// ---------------------------------------------------------------------------
// serving-level: plan-cached photonic shard under noise
// ---------------------------------------------------------------------------

const MANIFEST: &str = "\
gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8
gemm_0x8x4 g0.hlo.txt i32:0x8,i32:8x4 i32:0x4
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

fn synthetic_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spoga-prepacked-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn noisy_kind(seed: u64) -> BackendKind {
    BackendKind::Photonic(
        PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), seed),
    )
}

/// A plan-cached photonic shard under loud noise serves bit-identically to
/// a fresh engine at the same seed — across B-cache hits (same B twice),
/// refreshes (a different B in between), and an interleaved second artifact.
/// Content-keyed noise draws from the exact lane charges, which prepacking
/// preserves bit for bit, so the cache must be unobservable in the outputs.
#[test]
fn plan_cached_photonic_shard_under_noise_matches_fresh_engine() {
    let dir = synthetic_dir("noisy-cache");
    let seed = 0x7ACC_ED_B5;
    let c = Coordinator::start(CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        backend: noisy_kind(seed),
        max_batch_wait_s: 0.01,
        ..Default::default()
    })
    .unwrap();
    let h = c.handle();

    let a: Vec<i32> = (0..64).map(|v| ((v * 31) % 251) - 125).collect();
    let b1: Vec<i32> = (0..64).map(|v| ((v * 17) % 251) - 125).collect();
    let b2: Vec<i32> = (0..64).map(|v| ((v * 53 + 7) % 251) - 125).collect();

    // hit, refresh, refresh-back; plus the zero-row artifact in between so
    // the per-artifact caches prove they do not cross-contaminate.
    let traffic: Vec<(&str, &Vec<i32>, &Vec<i32>)> = vec![
        ("gemm_8x8x8", &a, &b1),
        ("gemm_8x8x8", &a, &b1),
        ("gemm_8x8x8", &a, &b2),
        ("gemm_8x8x8", &a, &b1),
    ];
    let mut served = Vec::new();
    for (artifact, a, b) in &traffic {
        served.push(h.gemm_reply(artifact, (*a).clone(), (*b).clone()).unwrap());
    }
    let zb: Vec<i32> = (0..32).map(|v| (v % 200) - 100).collect();
    let zero = h.gemm_reply("gemm_0x8x4", Vec::new(), zb.clone()).unwrap();
    assert!(zero.outputs.is_empty(), "zero-row artifact must serve empty under noise");
    // After the interleaved artifact, the first cache still answers exactly.
    served.push(h.gemm_reply("gemm_8x8x8", a.clone(), b1.clone()).unwrap());
    c.shutdown();

    // Oracle: a *fresh* engine per request at the same seed — no caches
    // carry over, only the (seed, content) noise key.
    let mut oracle = Vec::new();
    for (artifact, a, b) in traffic.iter().chain([&("gemm_8x8x8", &a, &b1)]) {
        let mut eng = Engine::with_backend(&dir, noisy_kind(seed)).unwrap();
        oracle.push(eng.execute_reported(artifact, &[a, b]).unwrap());
    }
    let mut noise_total = 0u64;
    for (i, (reply, (gold, gold_rep))) in served.iter().zip(&oracle).enumerate() {
        assert_eq!(reply.outputs, *gold, "request {i}: plan-cached outputs diverged");
        let (rep, gold_rep) = (reply.report.as_ref().unwrap(), gold_rep.as_ref().unwrap());
        assert_eq!(
            rep.noise_events, gold_rep.noise_events,
            "request {i}: noise accounting diverged"
        );
        assert_eq!(rep.row_noise, gold_rep.row_noise, "request {i}: row attribution");
        noise_total += rep.noise_events;
    }
    // Cache hits must return the *same* bits, and the property must bite:
    // a 0 dB channel actually perturbs.
    assert_eq!(served[0].outputs, served[1].outputs, "B-cache hit changed the answer");
    assert_eq!(served[0].outputs, served[3].outputs, "refresh-back changed the answer");
    assert_ne!(served[0].outputs, served[2].outputs, "different B must differ");
    assert!(noise_total > 0, "loud channel produced no noise events");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The same invariant on the *software* backend (weight-side packing cached
/// per plan): plan-cached GEMM replies equal a fresh engine's, exactly.
#[test]
fn plan_cached_software_shard_matches_fresh_engine() {
    let dir = synthetic_dir("sw-cache");
    let c = Coordinator::start(CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        backend: BackendKind::Software,
        max_batch_wait_s: 0.01,
        ..Default::default()
    })
    .unwrap();
    let h = c.handle();
    let a: Vec<i32> = (0..64).map(|v| ((v * 7) % 255) - 127).collect();
    let b1: Vec<i32> = (0..64).map(|v| ((v * 11) % 255) - 127).collect();
    let b2: Vec<i32> = (0..64).map(|v| -(((v * 11) % 255) - 127)).collect();

    for b in [&b1, &b1, &b2, &b1] {
        let reply = h.gemm_reply("gemm_8x8x8", a.clone(), b.clone()).unwrap();
        let mut eng = Engine::with_backend(&dir, BackendKind::Software).unwrap();
        let (gold, _) = eng.execute_reported("gemm_8x8x8", &[&a, b]).unwrap();
        assert_eq!(reply.outputs, gold);
    }
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
