//! Integration: the sharded multi-coordinator fleet.
//!
//! Pins the PR's acceptance contract, all against synthetic manifests so
//! nothing ever skips:
//!
//! * a 2-shard software|photonic fleet serves a mixed GEMM/MLP/CNN burst
//!   **bit-identically** to a 1-shard fleet (routing and t-stacked CNN
//!   batching never change served integers);
//! * batched CNN per-layer reports still match `sim::simulate_frame`
//!   exactly for the same accelerator;
//! * `FleetTelemetry` totals equal the sum of the per-shard stats;
//! * a noisy mixed software|photonic burst keeps the rollup-sum identity
//!   and `served_exact_fraction` consistency with CNN stacking still
//!   enabled (per-row noise attribution — no noise→batch=1 clamp);
//! * weighted routing splits deterministically, least-queue-depth prefers
//!   idle shards.

use std::path::PathBuf;
use std::sync::atomic::Ordering;

use spoga::arch::accel::Accelerator;
use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, Response, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::sim::engine::simulate_frame;
use spoga::testing::SplitMix64;

const MANIFEST: &str = "\
gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-fleet-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn shard_cfg(dir: &PathBuf, backend: BackendKind) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 1,
        backend,
        max_batch_wait_s: 0.01,
        ..Default::default()
    }
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "tiny_fleet",
        layers: vec![
            Layer::conv("stem", 8, 8, 3, 8, 3, 1, 1),
            Layer::dwconv("dw", 8, 8, 8, 3, 2, 1),
            Layer::fc("head", 4 * 4 * 8, 10),
        ],
    }
}

fn wire(rng: &mut SplitMix64, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.i8() as i32).collect()
}

/// Fire a deterministic mixed burst through a fleet handle (slot-based, so
/// co-pending CNN frames can batch) and return every reply's outputs in
/// submission order.
fn mixed_burst(h: &spoga::coordinator::FleetHandle) -> Vec<Vec<i32>> {
    let mut rng = SplitMix64::new(0xF1EE7);
    let model = tiny_cnn();
    let mut slots: Vec<Response> = Vec::new();
    for _ in 0..6 {
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        slots.push(h.submit_gemm("gemm_8x8x8", a, b).unwrap());
    }
    for t in 0..8 {
        let row: Vec<i32> = (0..16).map(|v| (v * 7 + t) % 100).collect();
        slots.push(h.submit_mlp(row).unwrap());
    }
    for f in 0..4 {
        let input: Vec<i32> = (0..8 * 8 * 3).map(|v| ((v * 13 + f * 71) % 251) - 125).collect();
        slots.push(h.submit_cnn(model.clone(), input).unwrap());
    }
    slots
        .into_iter()
        .map(|rx| rx.recv().expect("slot resolves").expect("request succeeds").outputs)
        .collect()
}

#[test]
fn two_shard_mixed_fleet_is_bit_identical_to_single_shard() {
    let dir = synthetic_dir("identical");

    let single = Fleet::single(shard_cfg(&dir, BackendKind::Software)).unwrap();
    let reference = mixed_burst(&single.handle());
    single.shutdown();

    let dual = Fleet::start(FleetConfig {
        shards: vec![
            shard_cfg(&dir, BackendKind::Software),
            shard_cfg(&dir, BackendKind::Photonic(PhotonicConfig::spoga())),
        ],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    let h = dual.handle();
    assert_eq!(h.shard_count(), 2);
    let served = mixed_burst(&h);
    assert_eq!(served, reference, "sharded serving changed served integers");

    // Both shards actually took traffic (round-robin over 18 requests).
    let fleet = h.telemetry();
    assert!(fleet.shards[0].requests > 0 && fleet.shards[1].requests > 0);
    assert_eq!(fleet.requests(), 18);
    assert_eq!(fleet.completed(), 18);
    assert_eq!(fleet.failed(), 0);
    // The photonic shard reported telemetry; the software shard did not.
    assert_eq!(fleet.shards[0].sim_reports, 0);
    assert!(fleet.shards[1].sim_reports > 0);
    assert!(fleet.sim_fps() > 0.0 && fleet.sim_fps_per_w() > 0.0);

    dual.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_telemetry_totals_equal_sum_of_per_shard_stats() {
    let dir = synthetic_dir("rollup");
    let fleet = Fleet::start(FleetConfig {
        shards: vec![
            shard_cfg(&dir, BackendKind::Software),
            shard_cfg(&dir, BackendKind::Photonic(PhotonicConfig::spoga())),
        ],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();
    let _ = mixed_burst(&h);

    let t = h.telemetry();
    let mut requests = 0;
    let mut completed = 0;
    let mut failed = 0;
    let mut sim_reports = 0;
    let mut lanes = 0;
    let mut sim_latency = 0.0;
    let mut energy = 0.0;
    for i in 0..h.shard_count() {
        let s = h.shard_stats(i);
        requests += s.requests.load(Ordering::Relaxed);
        completed += s.completed.load(Ordering::Relaxed);
        failed += s.failed.load(Ordering::Relaxed);
        sim_reports += s.sim_reports.load(Ordering::Relaxed);
        lanes += s.lanes.load(Ordering::Relaxed);
        sim_latency += s.sim_latency_total_s();
        energy += s.sim_energy_total_j();
    }
    assert_eq!(t.requests(), requests);
    assert_eq!(t.completed(), completed);
    assert_eq!(t.failed(), failed);
    assert_eq!(t.sim_reports(), sim_reports);
    assert_eq!(t.lanes(), lanes);
    assert!((t.sim_latency_total_s() - sim_latency).abs() <= 1e-15 * sim_latency.abs());
    assert!((t.sim_energy_total_j() - energy).abs() <= 1e-15 * energy.abs());

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batched_cnn_replies_match_simulate_frame_per_layer() {
    let dir = synthetic_dir("cnnbatch");
    let model = tiny_cnn();
    let pc = PhotonicConfig::spoga();
    let fleet =
        Fleet::single(shard_cfg(&dir, BackendKind::Photonic(pc.clone()))).unwrap();
    let h = fleet.handle();

    // Submit same-model frames back to back so the leader's batching
    // window stacks them along the t-dimension.
    let inputs: Vec<Vec<i32>> = (0..4)
        .map(|f| (0..8 * 8 * 3).map(|v| ((v * 17 + f * 101) % 251) - 125).collect())
        .collect();
    let slots: Vec<Response> = inputs
        .iter()
        .map(|input| h.submit_cnn(model.clone(), input.clone()).unwrap())
        .collect();
    let replies: Vec<_> = slots
        .into_iter()
        .map(|rx| rx.recv().unwrap().expect("cnn frame served"))
        .collect();

    // Every frame went through the CnnBatch path (the coordinator stacks
    // all CNN traffic when batching is enabled).
    let stats = h.shard_stats(0);
    let batches = stats.cnn_batches.load(Ordering::Relaxed);
    assert!(batches >= 1, "no stacked CNN batch executed");
    assert_eq!(stats.cnn_frames.load(Ordering::Relaxed), 4);

    // Per-layer telemetry must match the offline simulator exactly for
    // every frame, batched or not.
    let accel = Accelerator::equal_cores(pc.arch, pc.rate, pc.cores).unwrap();
    let frame = simulate_frame(&accel, &model.workload());
    for reply in &replies {
        assert_eq!(reply.layers.len(), frame.layers.len());
        for (served, simmed) in reply.layers.iter().zip(&frame.layers) {
            assert_eq!(served.layer, simmed.layer);
            let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-300);
            assert!(
                rel(served.report.sim_latency_s, simmed.latency_s) < 1e-12,
                "{}: batched served latency {} vs simulated {}",
                served.layer,
                served.report.sim_latency_s,
                simmed.latency_s
            );
            assert!(
                rel(served.report.energy_j, simmed.energy.total_j()) < 1e-12,
                "{}: batched served energy {} vs simulated {}",
                served.layer,
                served.report.energy_j,
                simmed.energy.total_j()
            );
        }
        let agg = reply.report.as_ref().expect("photonic aggregate");
        assert!((agg.sim_latency_s - frame.latency_s).abs() / frame.latency_s < 1e-12);
    }

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn noisy_mixed_fleet_keeps_rollup_identity_with_batching_on() {
    use spoga::fidelity::NoiseParams;
    let dir = synthetic_dir("noisy");
    let noisy = PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 0xBAD5EED);
    let fleet = Fleet::start(FleetConfig {
        shards: vec![
            shard_cfg(&dir, BackendKind::Software),
            shard_cfg(&dir, BackendKind::Photonic(noisy.clone())),
        ],
        policy: RoutePolicy::RoundRobin,
        labels: vec!["exact".into(), "noisy".into()],
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();
    // The noisy shard perturbs outputs, so no reference comparison — the
    // contract here is that everything serves, batching stays enabled, and
    // the telemetry identities hold.
    let served = mixed_burst(&h);
    assert_eq!(served.len(), 18);

    let t = h.telemetry();
    // Rollup-sum identity across every counter, including the noise pair.
    let mut sums = (0u64, 0u64, 0u64, 0u64, 0u64, 0u64);
    for i in 0..h.shard_count() {
        let s = h.shard_stats(i);
        sums.0 += s.requests.load(Ordering::Relaxed);
        sums.1 += s.completed.load(Ordering::Relaxed);
        sums.2 += s.failed.load(Ordering::Relaxed);
        sums.3 += s.lanes.load(Ordering::Relaxed);
        sums.4 += s.noise_events.load(Ordering::Relaxed);
        sums.5 += s.cnn_batches.load(Ordering::Relaxed);
    }
    assert_eq!(t.requests(), sums.0);
    assert_eq!(t.completed(), sums.1);
    assert_eq!((t.completed(), t.failed()), (18, 0));
    assert_eq!(t.failed(), sums.2);
    assert_eq!(t.lanes(), sums.3);
    assert_eq!(t.noise_events(), sums.4);
    // served_exact_fraction is consistent at every level: the fleet figure
    // is exactly 1 − Σ noise / Σ lanes of the shard stats.
    assert!((t.served_exact_fraction() - (1.0 - sums.4 as f64 / sums.3 as f64)).abs() < 1e-12);
    assert_eq!(t.shards[0].served_exact_fraction(), 1.0, "digital shard serves exactly");
    assert!(t.shards[1].served_exact_fraction() < 1.0, "0 dB shard must perturb");
    assert!(sums.4 > 0, "0 dB margin produced no noise events");

    // Round-robin over the burst hands the noisy shard CNN frames too —
    // and they stack: before per-row attribution the coordinator forced
    // noisy CNN serving unbatched (cnn_batches would be 0 there).
    let noisy_stats = h.shard_stats(1);
    assert!(noisy_stats.cnn_frames.load(Ordering::Relaxed) > 0);
    assert!(
        noisy_stats.cnn_batches.load(Ordering::Relaxed) > 0,
        "CNN stacking must stay enabled under noise injection"
    );

    // Per-request determinism through the noisy shard: identical GEMMs
    // observe identical content-keyed noise.
    let mut rng = SplitMix64::new(0xD0_77);
    let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
    let r1 = h.shard(1).gemm_reply("gemm_8x8x8", a.clone(), b.clone()).unwrap();
    let r2 = h.shard(1).gemm_reply("gemm_8x8x8", a, b).unwrap();
    assert_eq!(r1.outputs, r2.outputs);
    assert_eq!(r1.report, r2.report);
    let rep = r1.report.as_ref().unwrap();
    assert_eq!(rep.row_noise.iter().sum::<u64>(), rep.noise_events);

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn weighted_split_routes_deterministic_proportions() {
    let dir = synthetic_dir("weighted");
    let fleet = Fleet::start(FleetConfig {
        shards: vec![
            shard_cfg(&dir, BackendKind::Software),
            shard_cfg(&dir, BackendKind::Software),
        ],
        policy: RoutePolicy::Weighted(vec![1, 3]),
        labels: vec!["w1".into(), "w3".into()],
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();
    assert_eq!(h.shard_labels(), vec!["w1", "w3"]);

    let mut rng = SplitMix64::new(3);
    for _ in 0..8 {
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        h.gemm("gemm_8x8x8", a, b).unwrap();
    }
    // 1:3 over 8 sequential picks is exact: 2 and 6.
    assert_eq!(h.shard_stats(0).requests.load(Ordering::Relaxed), 2);
    assert_eq!(h.shard_stats(1).requests.load(Ordering::Relaxed), 6);

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn least_queue_depth_routes_to_idle_shard_under_serving() {
    let dir = synthetic_dir("least");
    let fleet = Fleet::start(FleetConfig {
        shards: vec![
            shard_cfg(&dir, BackendKind::Software),
            shard_cfg(&dir, BackendKind::Software),
        ],
        policy: RoutePolicy::LeastQueueDepth,
        labels: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();
    // Fake a backlog on shard 0: accepted-but-unresolved requests.
    h.shard_stats(0).requests.fetch_add(100, Ordering::Relaxed);
    let before = h.shard_stats(1).requests.load(Ordering::Relaxed);
    for t in 0..4 {
        let row: Vec<i32> = (0..16).map(|v| (v + t) % 50).collect();
        h.infer_mlp(row).unwrap();
    }
    assert_eq!(
        h.shard_stats(1).requests.load(Ordering::Relaxed),
        before + 4,
        "least-queue-depth must route everything to the idle shard"
    );
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
