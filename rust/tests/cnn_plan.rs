//! Compiled CNN plans vs the legacy wire path, bit for bit.
//!
//! `run_cnn_batch_keyed` serves through a [`CnnPlan`] (weights packed at
//! compile time, im2col into a persistent scratch arena, direct-i8 backend
//! entry); `run_cnn_batch_keyed_reference` is the retained pre-plan path
//! (ad-hoc wire-format GEMMs per layer group). The two must agree on
//! everything observable — logits, per-layer telemetry, noise attribution,
//! nonce decorrelation — on both backends, exact and noisy, batched and
//! unbatched. Also pins the frame-nonce length contract (typed error, not a
//! silent content-keyed fallback) and stream-many non-corruption when two
//! models alternate through one engine's plan cache.

use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::fidelity::NoiseParams;
use spoga::runtime::{
    run_cnn_batch_keyed, run_cnn_batch_keyed_reference, BackendKind, Engine, PhotonicConfig,
};
use spoga::Error;

fn tiny_model() -> CnnModel {
    CnnModel {
        name: "plan_tiny",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::dwconv("dw", 6, 6, 4, 3, 2, 1),
            Layer::fc("head", 3 * 3 * 4, 5),
        ],
    }
}

/// A second model with different geometry (grouped conv in the middle) to
/// alternate against `tiny_model` through one engine.
fn alt_model() -> CnnModel {
    CnnModel {
        name: "plan_alt",
        layers: vec![
            Layer::conv("c1", 5, 5, 2, 6, 3, 1, 0),
            Layer::fc("out", 3 * 3 * 6, 7),
        ],
    }
}

fn synthetic_dir(tag: &str) -> std::path::PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-cnn-plan-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), "mlp_b1 m i32:1x16 i32:1x4\n").unwrap();
    dir
}

fn frames(model: &CnnModel, n: usize, salt: usize) -> Vec<Vec<i32>> {
    let len = match &model.layers[0] {
        Layer::Conv { in_h, in_w, in_ch, .. } => in_h * in_w * in_ch,
        Layer::Fc { in_features, .. } => *in_features,
    };
    (0..n)
        .map(|f| (0..len).map(|v| (((v * 31) + (f + salt) * 97) % 251) as i32 - 125).collect())
        .collect()
}

fn backends() -> Vec<BackendKind> {
    vec![
        BackendKind::Software,
        BackendKind::Photonic(PhotonicConfig::spoga()),
        BackendKind::Photonic(
            PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 0xC4A7),
        ),
    ]
}

/// Run both paths on fresh engines of the same backend and demand complete
/// observable equality.
fn assert_paths_agree(kind: &BackendKind, model: &CnnModel, n: usize, nonces: &[u64]) {
    let dir = synthetic_dir(&format!("agree-{}-{n}-{}", kind.label(), nonces.len()));
    let inputs = frames(model, n, 0);
    let refs: Vec<&[i32]> = inputs.iter().map(|f| f.as_slice()).collect();
    let mut plan_eng = Engine::with_backend(&dir, kind.clone()).unwrap();
    let mut ref_eng = Engine::with_backend(&dir, kind.clone()).unwrap();
    let planned = run_cnn_batch_keyed(&mut plan_eng, model, &refs, nonces).unwrap();
    let legacy = run_cnn_batch_keyed_reference(&mut ref_eng, model, &refs, nonces).unwrap();
    assert_eq!(planned.len(), legacy.len());
    for (f, (p, l)) in planned.iter().zip(&legacy).enumerate() {
        assert_eq!(p.logits, l.logits, "{}: frame {f} logits diverged", kind.label());
        assert_eq!(p.report, l.report, "{}: frame {f} aggregate report", kind.label());
        assert_eq!(p.layers.len(), l.layers.len());
        for (pl, ll) in p.layers.iter().zip(&l.layers) {
            assert_eq!(pl.layer, ll.layer);
            assert_eq!(
                pl.report, ll.report,
                "{}: frame {f} layer {} telemetry diverged",
                kind.label(),
                pl.layer
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_path_matches_reference_exact_and_noisy() {
    let model = tiny_model();
    for kind in backends() {
        // Unbatched and batched, content-keyed.
        assert_paths_agree(&kind, &model, 1, &[]);
        assert_paths_agree(&kind, &model, 3, &[]);
    }
}

#[test]
fn plan_path_matches_reference_under_frame_nonces() {
    let model = tiny_model();
    for kind in backends() {
        assert_paths_agree(&kind, &model, 1, &[9]);
        assert_paths_agree(&kind, &model, 3, &[7, 0, 0xDEAD_BEEF]);
        // All-zero nonces are the content-keyed default, bit for bit.
        assert_paths_agree(&kind, &model, 2, &[0, 0]);
    }
}

#[test]
fn nonced_frames_still_decorrelate_through_the_plan_path() {
    // Two byte-identical frames under distinct nonces must observe
    // different noise through the compiled path (the decorrelation the
    // wire path already guarantees).
    let dir = synthetic_dir("decorrelate");
    let model = tiny_model();
    let kind = BackendKind::Photonic(
        PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 0xBEE5),
    );
    let mut eng = Engine::with_backend(&dir, kind).unwrap();
    let frame = frames(&model, 1, 0).remove(0);
    let twin: Vec<&[i32]> = vec![&frame, &frame];
    let plain = run_cnn_batch_keyed(&mut eng, &model, &twin, &[]).unwrap();
    assert_eq!(plain[0].logits, plain[1].logits, "content keying must correlate twins");
    let nonced = run_cnn_batch_keyed(&mut eng, &model, &twin, &[3, 4]).unwrap();
    assert_ne!(nonced[0].logits, nonced[1].logits, "distinct nonces must decorrelate twins");
    // Determinism: the same nonces replay the same observations.
    let again = run_cnn_batch_keyed(&mut eng, &model, &twin, &[3, 4]).unwrap();
    assert_eq!(nonced[0].logits, again[0].logits);
    assert_eq!(nonced[1].logits, again[1].logits);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn short_or_long_nonce_slices_are_typed_shape_errors() {
    // The bug this pins: a short nonce slice used to pass a release-mode
    // debug_assert and silently serve trailing frames content-keyed.
    let dir = synthetic_dir("noncelen");
    let model = tiny_model();
    let inputs = frames(&model, 3, 0);
    let refs: Vec<&[i32]> = inputs.iter().map(|f| f.as_slice()).collect();
    let mut eng = Engine::new(&dir).unwrap();
    for bad in [&[1u64][..], &[1, 2][..], &[1, 2, 3, 4][..]] {
        for result in [
            run_cnn_batch_keyed(&mut eng, &model, &refs, bad),
            run_cnn_batch_keyed_reference(&mut eng, &model, &refs, bad),
        ] {
            match result {
                Err(Error::Shape(msg)) => {
                    assert!(msg.contains("frame nonces"), "unhelpful message: {msg}")
                }
                other => panic!("expected shape error for {} nonces, got {other:?}", bad.len()),
            }
        }
    }
    // Exactly one nonce per frame (or none) is accepted.
    assert!(run_cnn_batch_keyed(&mut eng, &model, &refs, &[1, 2, 3]).is_ok());
    assert!(run_cnn_batch_keyed(&mut eng, &model, &refs, &[]).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn alternating_models_through_one_engine_stay_uncorrupted() {
    // Stream-many: two models ping-pong through one engine's plan cache and
    // shared scratch arena; every run must match a fresh-engine run of the
    // same model (no cross-model scratch or plan contamination).
    let dir = synthetic_dir("alternate");
    let (ma, mb) = (tiny_model(), alt_model());
    for kind in backends() {
        let mut eng = Engine::with_backend(&dir, kind.clone()).unwrap();
        for round in 0..3 {
            for model in [&ma, &mb] {
                let inputs = frames(model, 2, round);
                let refs: Vec<&[i32]> = inputs.iter().map(|f| f.as_slice()).collect();
                let shared = run_cnn_batch_keyed(&mut eng, model, &refs, &[]).unwrap();
                let mut fresh = Engine::with_backend(&dir, kind.clone()).unwrap();
                let alone = run_cnn_batch_keyed(&mut fresh, model, &refs, &[]).unwrap();
                for (s, a) in shared.iter().zip(&alone) {
                    assert_eq!(
                        s.logits,
                        a.logits,
                        "{}: round {round} model {} corrupted by alternation",
                        kind.label(),
                        model.name
                    );
                    assert_eq!(s.report, a.report);
                }
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn plan_cache_reuses_and_revalidates_by_model_equality() {
    let dir = synthetic_dir("cache");
    let mut eng = Engine::new(&dir).unwrap();
    let model = tiny_model();
    let p1 = eng.cnn_plan(&model).unwrap();
    let p2 = eng.cnn_plan(&model).unwrap();
    assert!(std::sync::Arc::ptr_eq(&p1, &p2), "equal model must reuse the compiled plan");
    // 1 PackedB per conv group + 1 for the FC: stem(1) + dw(4 groups) + head.
    assert_eq!(p1.packed_matrices(), 1 + 4 + 1);
    assert_eq!(p1.input_len(), 6 * 6 * 3);

    // A *different* model under the same name must recompile, not serve the
    // stale plan (full-equality revalidation, never name-keyed trust).
    let mut changed = tiny_model();
    changed.layers[2] = Layer::fc("head", 3 * 3 * 4, 9);
    let p3 = eng.cnn_plan(&changed).unwrap();
    assert!(!std::sync::Arc::ptr_eq(&p1, &p3), "changed model must recompile");
    let inputs = frames(&changed, 1, 0);
    let refs: Vec<&[i32]> = inputs.iter().map(|f| f.as_slice()).collect();
    let out = run_cnn_batch_keyed(&mut eng, &changed, &refs, &[]).unwrap();
    assert_eq!(out[0].logits.len(), 9);
    let _ = std::fs::remove_dir_all(&dir);
}
