//! Reproduction gates: the paper's quantitative claims, as tests.
//!
//! These encode the "shape" criterion from DESIGN.md §5 — who wins, by
//! roughly what factor — with generous bands (the substrate is a simulator,
//! not the authors' testbed). EXPERIMENTS.md records the exact values.

use spoga::arch::core::Core;
use spoga::arch::cost::ConversionCounts;
use spoga::dnn::layer::GemmShape;
use spoga::metrics::{build_figure, Metric, FIG5_CORES};
use spoga::optics::link_budget::ArchClass;
use spoga::optics::{paper_table1, solve_table1};
use spoga::units::DataRate;

/// Table I reproduces cell-for-cell (exact — it is an analytical model).
#[test]
fn gate_table1_exact() {
    let solved = solve_table1();
    let paper = paper_table1();
    for (s, p) in solved.rows.iter().zip(paper.rows.iter()) {
        assert_eq!(s.nm, p.nm, "row {}", s.label);
    }
}

/// Fig. 5(a): paper gmean factors 14.4× (vs DEAPCNN_10) and 11.1×
/// (vs HOLYLIGHT_10). Band: within 2× of the paper's factor.
#[test]
fn gate_fig5a_fps_factors() {
    let fig = build_figure(Metric::Fps, &[DataRate::Gs10], FIG5_CORES).unwrap();
    let rd = fig.gmean_ratio("SPOGA_10", "DEAPCNN_10").unwrap();
    let rh = fig.gmean_ratio("SPOGA_10", "HOLYLIGHT_10").unwrap();
    assert!(rd > 14.4 / 2.0 && rd < 14.4 * 2.0, "S/D FPS ratio {rd}");
    assert!(rh > 11.1 / 2.0 && rh < 11.1 * 2.0, "S/H FPS ratio {rh}");
    assert!(rd > rh, "paper ordering: DEAPCNN loses by more");
}

/// Fig. 5(b): paper gmean factors 2× and 1.3× at 10 GS/s.
#[test]
fn gate_fig5b_fps_per_watt_factors() {
    let fig = build_figure(Metric::FpsPerW, &[DataRate::Gs10], FIG5_CORES).unwrap();
    let rd = fig.gmean_ratio("SPOGA_10", "DEAPCNN_10").unwrap();
    let rh = fig.gmean_ratio("SPOGA_10", "HOLYLIGHT_10").unwrap();
    assert!(rd > 1.0 && rd < 2.0 * 2.0, "S/D FPS/W ratio {rd}");
    assert!(rh > 1.0 && rh < 1.3 * 2.5, "S/H FPS/W ratio {rh}");
}

/// Fig. 5(c): paper factors 28.5× (vs DEAPCNN_1) and 22.2× (vs
/// HOLYLIGHT_1) at 1 GS/s. Band: within 2×.
#[test]
fn gate_fig5c_area_efficiency_factors() {
    let fig = build_figure(Metric::FpsPerWPerMm2, &[DataRate::Gs1], FIG5_CORES).unwrap();
    let rd = fig.gmean_ratio("SPOGA_1", "DEAPCNN_1").unwrap();
    let rh = fig.gmean_ratio("SPOGA_1", "HOLYLIGHT_1").unwrap();
    assert!(rd > 28.5 / 2.0 && rd < 28.5 * 2.0, "S/D area-eff ratio {rd}");
    assert!(rh > 22.2 / 2.0 && rh < 22.2 * 2.0, "S/H area-eff ratio {rh}");
}

/// §III-B: per dot product, SPOGA needs 3 O/E + 1 ADC, no SRAM, no DEAS;
/// prior works need 4 O/E + 4 ADC + SRAM + DEAS.
#[test]
fn gate_conversion_accounting() {
    let spoga = Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap();
    let holy = Core::design(ArchClass::Maw, DataRate::Gs5, 10.0).unwrap();
    let sh = GemmShape { t: 1, k: spoga.n.min(holy.n), c: 16, groups: 1 };

    let sc = ConversionCounts::from_plan(&spoga.plan_gemm(&sh), sh.outputs());
    assert_eq!(sc.oe_per_output, 3.0);
    assert_eq!(sc.adc_per_output, 1.0);
    assert_eq!(sc.sram_bytes_per_output, 0.0);
    assert_eq!(sc.deas_per_output, 0.0);

    let hc = ConversionCounts::from_plan(&holy.plan_gemm(&sh), sh.outputs());
    assert!(hc.oe_per_output >= 4.0);
    assert!(hc.adc_per_output >= 4.0);
    assert!(hc.sram_bytes_per_output > 0.0);
    assert_eq!(hc.deas_per_output, 1.0);
}

/// SPOGA supports byte-size GEMM with the largest N×M (paper's Table I
/// takeaway) at every data rate.
#[test]
fn gate_spoga_highest_parallelism() {
    let t = solve_table1();
    let spoga = t.row("MWA (10dBm)").unwrap();
    for dr in DataRate::ALL {
        for base in ["HOLYLIGHT [3]", "DEAPCNN [9]"] {
            assert!(spoga.parallelism(dr) > t.row(base).unwrap().parallelism(dr));
        }
    }
}
