//! Integration: the pluggable-backend matrix.
//!
//! Runs entirely against a synthetic artifact manifest (the software and
//! photonic backends plan from manifest signatures alone), so these tests
//! never skip — unlike the `make artifacts` suites.
//!
//! Pins the PR's contract:
//! * software and photonic backends return **bit-identical** GEMM / MLP /
//!   CNN results, per-coordinator-configurable via `CoordinatorConfig`;
//! * photonic responses carry nonzero `sim_latency_s` / `energy_j`;
//! * `Job::Cnn` serves whole im2col inferences and its per-layer telemetry
//!   is consistent with `sim::simulate_frame` for the same accelerator.

use std::path::PathBuf;

use spoga::arch::accel::Accelerator;
use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::fidelity::NoiseParams;
use spoga::optics::link_budget::ArchClass;
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::sim::engine::simulate_frame;
use spoga::testing::SplitMix64;
use spoga::units::DataRate;

const MANIFEST: &str = "\
gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spoga-backend-matrix-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn cfg(dir: &PathBuf, backend: BackendKind) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        backend,
        max_batch_wait_s: 0.002,
        ..Default::default()
    }
}

fn wire(rng: &mut SplitMix64, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.i8() as i32).collect()
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "tiny_serve",
        layers: vec![
            Layer::conv("stem", 8, 8, 3, 8, 3, 1, 1),
            Layer::dwconv("dw", 8, 8, 8, 3, 2, 1),
            Layer::fc("head", 4 * 4 * 8, 10),
        ],
    }
}

#[test]
fn software_and_photonic_coordinators_agree_bit_for_bit() {
    let dir = synthetic_dir("agree");
    let sw = Coordinator::start(cfg(&dir, BackendKind::Software)).unwrap();
    let ph =
        Coordinator::start(cfg(&dir, BackendKind::Photonic(PhotonicConfig::spoga()))).unwrap();
    let (hs, hp) = (sw.handle(), ph.handle());

    let mut rng = SplitMix64::new(0xBEEF);
    // GEMM requests: identical outputs, photonic telemetry nonzero.
    for _ in 0..4 {
        let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
        let rs = hs.gemm_reply("gemm_8x8x8", a.clone(), b.clone()).unwrap();
        let rp = hp.gemm_reply("gemm_8x8x8", a, b).unwrap();
        assert_eq!(rs.outputs, rp.outputs, "backends disagree on GEMM");
        assert!(rs.report.is_none(), "software backend must not report telemetry");
        let r = rp.report.expect("photonic backend must report telemetry");
        assert!(r.sim_latency_s > 0.0, "sim_latency_s = {}", r.sim_latency_s);
        assert!(r.energy_j > 0.0, "energy_j = {}", r.energy_j);
        assert_eq!(r.lanes, 64);
        assert_eq!(r.noise_events, 0, "noise off by default");
    }

    // MLP rows: identical logits through the dynamic batcher.
    for t in 0..8 {
        let row: Vec<i32> = (0..16).map(|v| (v * 7 + t) % 100).collect();
        let ls = hs.infer_mlp(row.clone()).unwrap();
        let lp = hp.infer_mlp(row).unwrap();
        assert_eq!(ls, lp, "backends disagree on MLP row {t}");
    }

    // Photonic stats aggregated live telemetry; software did not.
    assert!(hp.stats().sim_fps() > 0.0);
    assert!(hp.stats().sim_fps_per_w() > 0.0);
    assert_eq!(
        hs.stats().sim_reports.load(std::sync::atomic::Ordering::Relaxed),
        0
    );

    sw.shutdown();
    ph.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cnn_job_end_to_end_with_simulator_consistent_telemetry() {
    let dir = synthetic_dir("cnn");
    let model = tiny_cnn();
    let input: Vec<i32> = {
        let mut rng = SplitMix64::new(2024);
        wire(&mut rng, 8 * 8 * 3)
    };

    let sw = Coordinator::start(cfg(&dir, BackendKind::Software)).unwrap();
    let ph =
        Coordinator::start(cfg(&dir, BackendKind::Photonic(PhotonicConfig::spoga()))).unwrap();

    let reply_sw = sw.handle().infer_cnn(model.clone(), input.clone()).unwrap();
    let reply_ph = ph.handle().infer_cnn(model.clone(), input.clone()).unwrap();

    // Full inference served; bit-identical logits across backends.
    assert_eq!(reply_sw.outputs.len(), 10);
    assert_eq!(reply_sw.outputs, reply_ph.outputs);
    assert!(reply_sw.report.is_none() && reply_sw.layers.is_empty());

    // Per-layer telemetry must match the offline simulator exactly: the
    // photonic backend prices each layer's grouped GEMM through the same
    // SimEngine that simulate_frame uses.
    let pc = PhotonicConfig::spoga();
    let accel = Accelerator::equal_cores(pc.arch, pc.rate, pc.cores).unwrap();
    let frame = simulate_frame(&accel, &model.workload());
    assert_eq!(reply_ph.layers.len(), frame.layers.len());
    for (served, simmed) in reply_ph.layers.iter().zip(&frame.layers) {
        assert_eq!(served.layer, simmed.layer);
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-300);
        assert!(
            rel(served.report.sim_latency_s, simmed.latency_s) < 1e-12,
            "{}: served latency {} vs simulated {}",
            served.layer,
            served.report.sim_latency_s,
            simmed.latency_s
        );
        assert!(
            rel(served.report.energy_j, simmed.energy.total_j()) < 1e-12,
            "{}: served energy {} vs simulated {}",
            served.layer,
            served.report.energy_j,
            simmed.energy.total_j()
        );
    }
    // ... and the aggregate matches the whole frame.
    let agg = reply_ph.report.unwrap();
    assert!((agg.sim_latency_s - frame.latency_s).abs() / frame.latency_s < 1e-12);
    assert!((agg.energy_j - frame.energy.total_j()).abs() / frame.energy.total_j() < 1e-12);
    assert_eq!(agg.lanes, model.workload().total_outputs());

    // Stats counted the CNN frame.
    let stats = ph.handle();
    assert_eq!(stats.stats().cnn_frames.load(std::sync::atomic::Ordering::Relaxed), 1);

    // Chain validation rejects bad inputs at submit time.
    assert!(sw.handle().submit_cnn(model.clone(), vec![0; 7]).is_err());

    sw.shutdown();
    ph.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn cnn_trace_submission_and_baseline_comparison() {
    let dir = synthetic_dir("trace");
    const TRACE: &str = "\
model edge_tiny
conv stem 8 8 3 8 3 1 1 1
fc head 512 10
";
    let input = vec![3i32; 8 * 8 * 3];

    // Same traffic, three accelerator design points — the live A/B the
    // tentpole exists for.
    let mut energies = Vec::new();
    for pc in [PhotonicConfig::spoga(), PhotonicConfig::holylight(), PhotonicConfig::deapcnn()] {
        let c = Coordinator::start(cfg(&dir, BackendKind::Photonic(pc))).unwrap();
        let reply = c
            .handle()
            .submit_cnn_trace(TRACE, input.clone())
            .unwrap()
            .recv()
            .unwrap()
            .unwrap();
        energies.push(reply.report.unwrap().energy_j);
        c.shutdown();
    }
    // SPOGA's conversion chain (3 O/E + 1 ADC, no DEAS/SRAM) must beat the
    // baselines on energy for identical traffic.
    assert!(energies[0] < energies[1], "SPOGA {} vs HOLYLIGHT {}", energies[0], energies[1]);
    assert!(energies[0] < energies[2], "SPOGA {} vs DEAPCNN {}", energies[0], energies[2]);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn noise_injected_backend_reports_noise_events() {
    let dir = synthetic_dir("noise");
    let noisy = PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 7);
    let c = Coordinator::start(cfg(&dir, BackendKind::Photonic(noisy))).unwrap();
    let h = c.handle();
    let mut rng = SplitMix64::new(1);
    let (a, b) = (wire(&mut rng, 64), wire(&mut rng, 64));
    let reply = h.gemm_reply("gemm_8x8x8", a, b).unwrap();
    let r = reply.report.unwrap();
    assert!(r.noise_events > 0, "0 dB margin on K=8 must perturb outputs");
    assert!(h.stats().noise_events.load(std::sync::atomic::Ordering::Relaxed) > 0);
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn photonic_backend_matches_equivalent_simulated_accelerator_classes() {
    // Sanity on the config plumbing: the three presets really map to the
    // three ArchClass design points.
    assert!(matches!(PhotonicConfig::spoga().arch, ArchClass::Mwa));
    assert!(matches!(PhotonicConfig::holylight().arch, ArchClass::Maw));
    assert!(matches!(PhotonicConfig::deapcnn().arch, ArchClass::Amw));
    assert_eq!(PhotonicConfig::spoga().rate, DataRate::Gs10);
}
