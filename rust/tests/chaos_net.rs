//! Process-level chaos over the wire protocol.
//!
//! Pins the cross-host PR's acceptance contract:
//!
//! * a REAL child shard-server process (`spoga serve --listen 127.0.0.1:0`)
//!   is SIGKILLed mid-burst, and every in-flight retrying slot resolves
//!   bit-identically to an undisturbed local run through retained-payload
//!   resubmission on the surviving local shard — for the software backend
//!   AND a noise-injecting photonic backend (content-keyed noise at equal
//!   seeds is process-independent);
//! * protocol failure injection against fake in-test peers produces the
//!   *typed* `Error::Remote` kind, within a bounded deadline, with the
//!   correct retirement decision: corrupt frame → `FrameCorrupt` +
//!   in-place reconnect (shard stays in rotation), version skew →
//!   `VersionMismatch` (ditto), truncated write → `PeerGone` (retired),
//!   stalled peer (accept-then-silence) → `Timeout` at `io_timeout`
//!   (never a hang, never a retirement);
//! * a mixed local+remote fleet whose every shard dies resolves retained
//!   payloads with a terminal shard-down error, counted exactly once in
//!   `FleetLifecycle::terminal_failures` (no double-count from the
//!   submit-time and mid-flight paths).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use spoga::coordinator::{
    CoordinatorConfig, Fleet, FleetConfig, FleetHandle, RemoteShardConfig, RetryingSlot,
    RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::error::RemoteErrorKind;
use spoga::fidelity::NoiseParams;
use spoga::net::{NetConfig, RemoteShard, ServeTarget, ShardServer};
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::testing::SplitMix64;
use spoga::Error;

const MANIFEST: &str = "\
gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

/// The noise seed `spoga serve --noise-margin` defaults to; the local
/// reference shards must key their noise identically for bit-identity.
const NOISE_SEED: u64 = 0xDEAD_5EED;

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-chaos-net-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn shard_cfg(dir: &PathBuf, backend: BackendKind, window_s: f64) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        backend,
        max_batch_wait_s: window_s,
        ..Default::default()
    }
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "tiny_net_chaos",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::fc("head", 6 * 6 * 4, 5),
        ],
    }
}

/// Deterministic mixed burst of retrying slots, in a fixed submission
/// order (GEMMs dispatch immediately; MLP rows and CNN frames gather in
/// the batching window — the mid-flight exposure).
fn submit_burst(h: &FleetHandle) -> Vec<RetryingSlot> {
    let mut rng = SplitMix64::new(0x0C4A05);
    let model = tiny_cnn();
    let mut slots = Vec::new();
    for _ in 0..4 {
        let a: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
        let b: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
        slots.push(h.submit_gemm_retrying("gemm_8x8x8", a, b).unwrap());
    }
    for t in 0..6 {
        let row: Vec<i32> = (0..16).map(|v| (v * 13 + t * 7) % 100).collect();
        slots.push(h.submit_mlp_retrying(row).unwrap());
    }
    for f in 0..4 {
        let input: Vec<i32> =
            (0..6 * 6 * 3).map(|v| ((v * 17 + f * 71) % 251) - 125).collect();
        slots.push(h.submit_cnn_retrying(model.clone(), input).unwrap());
    }
    slots
}

fn recv_all(slots: Vec<RetryingSlot>) -> Vec<Vec<i32>> {
    slots
        .into_iter()
        .map(|s| {
            s.recv_timeout(Duration::from_secs(60))
                .expect("retrying slot must resolve OK across process death")
                .outputs
        })
        .collect()
}

/// Spawn a real `spoga serve --listen 127.0.0.1:0` child over `dir`'s
/// artifacts and parse the OS-assigned address from its stdout.
fn spawn_server(dir: &PathBuf, extra: &[&str]) -> (Child, String) {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_spoga"));
    cmd.args(["serve", "--listen", "127.0.0.1:0", "--workers", "2", "--window", "0.5"])
        .args(["--artifacts", &dir.to_string_lossy()])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
    let mut child = cmd.spawn().expect("spawn spoga serve child");
    let stdout = child.stdout.take().expect("child stdout piped");
    let mut lines = BufReader::new(stdout).lines();
    let addr = loop {
        let line = lines
            .next()
            .expect("child exited before announcing its address")
            .expect("read child stdout");
        if let Some(a) = line.strip_prefix("listening on ") {
            break a.to_string();
        }
    };
    // Keep draining stdout so the child can never block on a full pipe.
    std::thread::spawn(move || for _line in lines {});
    (child, addr)
}

/// The headline acceptance test: SIGKILL a child server process while it
/// holds accepted requests in its batching window. Every retrying slot
/// must resolve on the surviving local shard, bit-identical to an
/// undisturbed local run — exact and noisy backends alike.
#[test]
fn sigkill_mid_burst_resolves_bit_identically_on_the_survivor() {
    let noisy = BackendKind::Photonic(
        PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), NOISE_SEED),
    );
    let cases: [(&str, &[&str], BackendKind); 2] = [
        ("sw", &[], BackendKind::Software),
        ("noisy", &["--backend", "photonic", "--noise-margin", "0"], noisy),
    ];
    for (tag, child_args, backend) in cases {
        let dir = synthetic_dir(&format!("sigkill-{tag}"));

        // Reference: undisturbed local single-shard run over the same burst.
        let single = Fleet::single(shard_cfg(&dir, backend.clone(), 0.0)).unwrap();
        let reference = recv_all(submit_burst(&single.handle()));
        single.shutdown();

        // Chaos run: one local shard + one REAL child server process. The
        // child's 0.5 s batching window holds its accepted MLP/CNN jobs
        // when the SIGKILL lands — the mid-flight loss case, across a
        // process boundary.
        let (mut child, addr) = spawn_server(&dir, child_args);
        let fleet = Fleet::start(FleetConfig {
            shards: vec![shard_cfg(&dir, backend.clone(), 0.1)],
            remotes: vec![RemoteShardConfig::new(addr)],
            policy: RoutePolicy::RoundRobin,
            ..Default::default()
        })
        .unwrap();
        let h = fleet.handle();
        assert_eq!(h.shard_count(), 2, "{tag}: fleet must hold local + remote slots");

        let slots = submit_burst(&h);
        // All submits are on the wire or accepted; now the peer process
        // dies without any goodbye.
        child.kill().expect("SIGKILL child server");
        child.wait().expect("reap child server");

        let served = recv_all(slots);
        assert_eq!(
            served, reference,
            "{tag}: cross-process retry changed served integers"
        );
        let t = h.telemetry();
        assert!(
            t.resubmits + t.submit_reroutes > 0,
            "{tag}: no payload moved shards — the chaos case was not exercised"
        );
        assert_eq!(
            t.terminal_failures, 0,
            "{tag}: a surviving shard means no retained payload may end terminal"
        );
        assert_eq!(
            h.live_shard_count(),
            1,
            "{tag}: the killed server's slot must leave the rotation"
        );
        fleet.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Accept a connection on a nonblocking listener within `timeout`.
fn accept_within(listener: &TcpListener, timeout: Duration) -> TcpStream {
    listener.set_nonblocking(true).unwrap();
    let deadline = Instant::now() + timeout;
    loop {
        match listener.accept() {
            Ok((s, _)) => return s,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                assert!(Instant::now() < deadline, "peer never connected");
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => panic!("accept: {e}"),
        }
    }
}

fn remote_kind(e: &Error) -> RemoteErrorKind {
    match e {
        Error::Remote { kind, .. } => *kind,
        other => panic!("expected a typed Error::Remote, got {other:?}"),
    }
}

/// A peer that answers a submit with a garbage frame: the pending request
/// fails with `FrameCorrupt` (request-level), and the client repairs the
/// stream in place — the shard is NOT retired.
#[test]
fn corrupt_reply_frame_is_typed_and_does_not_retire_the_shard() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let shard = RemoteShard::connect(&addr, "corrupt-peer", NetConfig::default()).unwrap();
    let mut conn = accept_within(&listener, Duration::from_secs(5));
    conn.set_nonblocking(false).unwrap();

    let rx = shard.try_submit_mlp(vec![1; 16]).expect("submit writes fine");
    // Wait for the submit frame to land, then answer with 28 bytes of junk
    // (bad magic): the client's framing cannot resynchronize a byte stream.
    let mut first = [0u8; 1];
    conn.read_exact(&mut first).unwrap();
    let mut junk = [0u8; 28];
    junk[..4].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
    conn.write_all(&junk).unwrap();

    let err = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("pending request must fail, not hang")
        .expect_err("junk can not be a valid reply");
    assert_eq!(remote_kind(&err), RemoteErrorKind::FrameCorrupt, "{err}");

    // The client reconnects in place (the listener sees a second dial) and
    // the shard stays in rotation: FrameCorrupt never retires.
    let _conn2 = accept_within(&listener, Duration::from_secs(10));
    assert!(shard.is_reachable(), "a corrupt frame must not retire the shard");
    shard.disconnect();
}

/// A peer speaking a different protocol version: `VersionMismatch`, again
/// request-level (the build is wrong, not the network).
#[test]
fn version_skewed_peer_is_typed_version_mismatch() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let shard = RemoteShard::connect(&addr, "skewed-peer", NetConfig::default()).unwrap();
    let mut conn = accept_within(&listener, Duration::from_secs(5));
    conn.set_nonblocking(false).unwrap();

    let rx = shard.try_submit_mlp(vec![2; 16]).expect("submit writes fine");
    let mut first = [0u8; 1];
    conn.read_exact(&mut first).unwrap();
    // Valid magic, version 999, zero-length payload: rejected on the
    // version field before any checksum math.
    let mut header = Vec::new();
    header.extend_from_slice(b"SPOG");
    header.extend_from_slice(&999u16.to_le_bytes());
    header.extend_from_slice(&[4, 0]); // opcode Reply, reserved
    header.extend_from_slice(&7u64.to_le_bytes()); // request id (any)
    header.extend_from_slice(&0u32.to_le_bytes()); // payload len
    header.extend_from_slice(&0u64.to_le_bytes()); // checksum (unchecked)
    conn.write_all(&header).unwrap();

    let err = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("pending request must fail, not hang")
        .expect_err("version skew can not resolve a request");
    assert_eq!(remote_kind(&err), RemoteErrorKind::VersionMismatch, "{err}");
    shard.disconnect();
}

/// A peer that truncates mid-frame and closes: `PeerGone`, and this time
/// the shard IS retired — the connection is genuinely dead.
#[test]
fn truncated_reply_then_close_is_peer_gone_and_retires() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();

    let shard =
        RemoteShard::connect(&addr, "truncating-peer", NetConfig::default()).unwrap();
    let mut conn = accept_within(&listener, Duration::from_secs(5));
    conn.set_nonblocking(false).unwrap();

    let rx = shard.try_submit_mlp(vec![3; 16]).expect("submit writes fine");
    let mut first = [0u8; 1];
    conn.read_exact(&mut first).unwrap();
    // Write a valid-looking frame prefix, then vanish (listener included —
    // the process is "gone", not confused).
    let mut partial = Vec::new();
    partial.extend_from_slice(b"SPOG");
    partial.extend_from_slice(&1u16.to_le_bytes());
    conn.write_all(&partial).unwrap();
    drop(conn);
    drop(listener);

    let t0 = Instant::now();
    let err = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("pending request must fail, not hang")
        .expect_err("a truncated stream can not resolve a request");
    assert_eq!(remote_kind(&err), RemoteErrorKind::PeerGone, "{err}");
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "peer-gone classification must not burn the full io_timeout"
    );

    // Retirement: peer-gone is immediate (no in-place repair — revival is
    // the fleet janitor's job), so the gauge drops to 0 and the router
    // would fail this slot over.
    let deadline = Instant::now() + Duration::from_secs(10);
    while shard.is_reachable() {
        assert!(Instant::now() < deadline, "dead peer never retired the shard");
        std::thread::sleep(Duration::from_millis(10));
    }
    shard.disconnect();
}

/// A peer that accepts and then says nothing: every pending request trips
/// the io_timeout deadline with `Timeout` — bounded, typed, and with the
/// shard left in rotation (a slow peer is not a dead peer).
#[test]
fn stalled_peer_trips_io_timeout_not_a_hang() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    // The OS accepts into the backlog; never reading is the stall.
    let cfg = NetConfig::default().with_io_timeout(Duration::from_millis(300));
    let shard = RemoteShard::connect(&addr, "stalled-peer", cfg).unwrap();

    let t0 = Instant::now();
    let rx = shard.try_submit_mlp(vec![4; 16]).expect("submit writes into the void");
    let err = rx
        .recv_timeout(Duration::from_secs(10))
        .expect("deadline must fire, not hang")
        .expect_err("a silent peer can not resolve a request");
    assert_eq!(remote_kind(&err), RemoteErrorKind::Timeout, "{err}");
    let elapsed = t0.elapsed();
    assert!(
        elapsed >= Duration::from_millis(250),
        "timeout fired before the configured io_timeout ({elapsed:?})"
    );
    assert!(
        elapsed < Duration::from_secs(5),
        "timeout must fire near io_timeout, not at some larger deadline ({elapsed:?})"
    );
    assert!(shard.is_reachable(), "a stalled request must not retire the shard");

    // Pings run the same deadline machinery.
    let err = shard.ping(Duration::from_millis(300)).unwrap_err();
    assert_eq!(remote_kind(&err), RemoteErrorKind::Timeout, "{err}");
    shard.disconnect();
    drop(listener);
}

/// Satellite: a mixed local+remote fleet where EVERY shard dies. The
/// retained payload's resubmission finds no live shard, resolves with a
/// terminal shard-down error, and `terminal_failures` counts it exactly
/// once — submit-time refusals afterwards do not inflate it.
#[test]
fn mixed_fleet_exhaustion_is_terminal_and_counted_once() {
    let dir = synthetic_dir("exhaust");

    // Remote side: an in-process server fronting its own 1-shard fleet.
    let backend_fleet = Fleet::single(shard_cfg(&dir, BackendKind::Software, 0.0)).unwrap();
    let server = ShardServer::start(
        "127.0.0.1:0",
        ServeTarget::Fleet(backend_fleet.handle()),
        NetConfig::default(),
    )
    .unwrap();

    // Client side: one local shard with a long window + the remote.
    let fleet = Fleet::start(FleetConfig {
        shards: vec![shard_cfg(&dir, BackendKind::Software, 0.5)],
        remotes: vec![RemoteShardConfig::new(server.local_addr().to_string())],
        policy: RoutePolicy::RoundRobin,
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();

    // One retrying MLP row lands in the local shard's batching window...
    let slot = h.submit_mlp_retrying(vec![3i32; 16]).unwrap();
    // ...then every shard dies: the local pool is retired and the remote
    // server (plus its fleet) shuts down.
    h.shard(0).retire_workers().unwrap();
    server.shutdown();
    backend_fleet.shutdown();

    let err = slot.recv_timeout(Duration::from_secs(30)).unwrap_err();
    assert!(
        matches!(&err, Error::ShardDown(_))
            || matches!(&err, Error::Remote { kind, .. } if kind.retires_shard()),
        "terminal disposition must be shard-down classified, got {err:?}"
    );
    let t = h.telemetry();
    assert_eq!(
        t.terminal_failures, 1,
        "one retained payload ended terminal — it must count exactly once"
    );

    // With the whole fleet down, new retrying submits fail fast — and that
    // submit-time refusal is NOT a retained payload's terminal disposition.
    assert!(h.submit_mlp_retrying(vec![5i32; 16]).is_err());
    assert_eq!(
        h.telemetry().terminal_failures,
        1,
        "submit-time refusals must not double-count terminal failures"
    );
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// End-to-end CLI smoke over the wire: a child `serve --listen` process
/// answers a `RemoteShard` burst, reports server-side stats over the Stats
/// opcode, and exits on the Shutdown opcode — the orderly half of the
/// process lifecycle (the SIGKILL test covers the disorderly half).
#[test]
fn child_server_serves_stats_and_shuts_down_cleanly() {
    let dir = synthetic_dir("orderly");
    let (mut child, addr) = spawn_server(&dir, &[]);

    let shard = RemoteShard::connect(&addr, "orderly", NetConfig::default()).unwrap();
    shard.ping(Duration::from_secs(10)).expect("child server must pong end-to-end");
    for i in 0..8 {
        let rx = shard.try_submit_mlp((0..16).map(|v| (v + i) % 50).collect()).unwrap();
        let reply = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("slot resolves")
            .expect("remote serve succeeds");
        assert_eq!(reply.outputs.len(), 4);
    }
    let stats = shard.fetch_stats(Duration::from_secs(10)).expect("stats RPC");
    assert!(
        stats.completed >= 8,
        "server-side telemetry must count the burst, got {}",
        stats.completed
    );

    shard.request_server_shutdown().expect("shutdown opcode writes");
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        match child.try_wait().expect("poll child") {
            Some(status) => {
                assert!(status.success(), "orderly shutdown must exit 0, got {status}");
                break;
            }
            None => {
                assert!(Instant::now() < deadline, "child never exited on Shutdown opcode");
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    shard.disconnect();
    let _ = std::fs::remove_dir_all(&dir);
}
