//! Tier-1 static invariants: run `spoga-lint` over the crate's own sources
//! and pin the linter's behavior with per-rule fixtures.
//!
//! The whole-tree test is the ratchet: a regression that reintroduces a
//! poison panic, an unjustified `unsafe`, a release-silent guard, a wire
//! codec asymmetry, or a blocking ingress send fails `cargo test -q`
//! before it reaches review. The fixture tests are the linter's own
//! contract: one firing and one non-firing case per rule, plus the
//! `lint:allow` escape-hatch semantics, so rule changes are visible diffs
//! here rather than silent behavior shifts.

use spoga::analysis::{lint_source, rules};
use std::path::Path;

// ---------------------------------------------------------------------------
// The ratchet: the entire crate lints clean, with zero standing exceptions.
// ---------------------------------------------------------------------------

#[test]
fn entire_crate_lints_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = spoga::analysis::lint_dir(&root).expect("walk rust/src");
    // Guard against a silently-empty walk (wrong root, renamed tree): the
    // crate has well over 40 source files and only ever grows.
    assert!(
        report.files >= 40,
        "suspiciously few files scanned ({}): wrong root?",
        report.files
    );
    assert!(report.is_clean(), "static invariant violations:\n{}", report.render());
    // The tree currently carries zero lint:allow exceptions. If one becomes
    // genuinely necessary, justify it at the site and bump this pin — the
    // count is the visible ledger of intentional deviations.
    assert_eq!(
        report.suppressions.len(),
        0,
        "unexpected lint:allow exceptions:\n{}",
        report.render()
    );
}

// ---------------------------------------------------------------------------
// R1 no-poison-panic
// ---------------------------------------------------------------------------

fn rules_of(report: &spoga::analysis::LintReport) -> Vec<&str> {
    report.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn poison_panic_fires_on_lock_unwrap() {
    let src = "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n";
    let r = lint_source("fixture.rs", src);
    assert_eq!(rules_of(&r), vec![rules::NO_POISON_PANIC]);
    assert_eq!(r.findings[0].line, 2);
}

#[test]
fn poison_panic_sees_through_formatting_and_counts_every_chain() {
    // A multi-line builder chain and a RwLock read().expect() — two
    // violations, neither hidden by line breaks.
    let src = "fn f(m: &std::sync::Mutex<u8>, r: &std::sync::RwLock<u8>) -> u8 {\n\
               \x20   let a = *m\n\
               \x20       .lock()\n\
               \x20       .unwrap();\n\
               \x20   a + *r.read().expect(\"poisoned\")\n\
               }\n";
    let r = lint_source("fixture.rs", src);
    assert_eq!(rules_of(&r), vec![rules::NO_POISON_PANIC, rules::NO_POISON_PANIC]);
}

#[test]
fn poison_panic_ignores_recovery_idioms_and_test_code() {
    let src = "\
fn recovered(m: &std::sync::Mutex<u8>) -> u8 {
    *m.lock().unwrap_or_else(|p| p.into_inner())
}
fn typed(m: &std::sync::Mutex<u8>) -> Result<u8, String> {
    Ok(*m.lock().map_err(|_| \"poisoned\".to_string())?)
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let m = std::sync::Mutex::new(1u8);
        assert_eq!(*m.lock().unwrap(), 1);
    }
}
";
    let r = lint_source("fixture.rs", src);
    assert!(r.is_clean(), "{}", r.render());
}

// ---------------------------------------------------------------------------
// R2 safety-comment
// ---------------------------------------------------------------------------

#[test]
fn safety_comment_fires_on_bare_unsafe() {
    let src = "fn f(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n";
    let r = lint_source("fixture.rs", src);
    assert_eq!(rules_of(&r), vec![rules::SAFETY_COMMENT]);
    assert_eq!(r.findings[0].line, 2);
}

#[test]
fn safety_comment_accepts_adjacent_and_above_attribute_comments() {
    // Directly above the block, and above an attribute prologue on an
    // `unsafe fn` declaration — both placements discharge the rule.
    let src = "\
fn f(p: *const u8) -> u8 {
    // SAFETY: caller passed a pointer derived from a live &u8.
    unsafe { *p }
}
// SAFETY: the attribute changes codegen only; the body is safe slice code.
#[target_feature(enable = \"avx2\")]
#[allow(dead_code)]
unsafe fn g(x: &[u8]) -> u8 {
    x[0]
}
";
    let r = lint_source("fixture.rs", src);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn safety_comment_rejects_doc_safety_sections_as_substitutes() {
    // A doc `# Safety` section states the *caller's* obligation — it does
    // not justify the site itself, so the rule still fires.
    let src = "\
/// # Safety
/// `p` must be valid for reads.
pub unsafe fn f(p: *const u8) -> u8 {
    *p
}
";
    let r = lint_source("fixture.rs", src);
    assert_eq!(rules_of(&r), vec![rules::SAFETY_COMMENT]);
}

// ---------------------------------------------------------------------------
// R3 no-release-silent-guards
// ---------------------------------------------------------------------------

const R3_FIRING: &str = "\
struct B { runs: Vec<u8>, jobs: Vec<u8> }
impl B {
    fn deliver(&self) {
        debug_assert_eq!(self.runs.len(), self.jobs.len());
    }
}
";

#[test]
fn release_silent_guard_fires_on_serving_state_predicates() {
    let r = lint_source("coordinator/fixture.rs", R3_FIRING);
    assert_eq!(rules_of(&r), vec![rules::NO_RELEASE_SILENT_GUARDS]);
    assert_eq!(r.findings[0].line, 4);
}

#[test]
fn release_silent_guard_ignores_non_serving_predicates_and_testing_tree() {
    let benign = "fn f(capacity: usize) {\n    debug_assert!(capacity.is_power_of_two());\n}\n";
    let r = lint_source("coordinator/fixture.rs", benign);
    assert!(r.is_clean(), "{}", r.render());
    // The same serving-state predicate is fine under testing/ — harness
    // internals are not the serving path.
    let r = lint_source("testing/fixture.rs", R3_FIRING);
    assert!(r.is_clean(), "{}", r.render());
}

// ---------------------------------------------------------------------------
// R4 wire-codec-symmetry
// ---------------------------------------------------------------------------

/// A miniature wire module that satisfies every R4 clause: all variants in
/// `from_u8`, paired codecs, a codec pair for the payload (`Submit*`)
/// opcode, control opcodes bare, and error tags that round trip.
const R4_CLEAN: &str = "\
pub enum Opcode {
    SubmitGemm = 1,
    Reply = 2,
    Ping = 3,
}
impl Opcode {
    pub fn from_u8(v: u8) -> Option<Opcode> {
        match v {
            1 => Some(Opcode::SubmitGemm),
            2 => Some(Opcode::Reply),
            3 => Some(Opcode::Ping),
            _ => None,
        }
    }
}
pub enum E { A(String), B(String) }
pub fn encode_gemm(a: &[i32]) -> Vec<u8> { vec![a.len() as u8] }
pub fn decode_gemm(b: &[u8]) -> usize { b.len() }
pub fn encode_reply(n: usize) -> Vec<u8> { vec![n as u8] }
pub fn decode_reply(b: &[u8]) -> usize { b.len() }
pub fn encode_error(e: &E) -> (u8, String) {
    match e {
        E::A(m) => (0, m.clone()),
        E::B(m) => (1, m.clone()),
    }
}
pub fn decode_error(tag: u8, m: String) -> E {
    match tag {
        0 => E::A(m),
        1 => E::B(m),
        _ => E::A(m),
    }
}
";

#[test]
fn wire_codec_symmetry_accepts_a_symmetric_module() {
    let r = lint_source("net/fixture.rs", R4_CLEAN);
    assert!(r.is_clean(), "{}", r.render());
}

#[test]
fn wire_codec_symmetry_catches_a_variant_missing_from_from_u8() {
    let src = R4_CLEAN.replace("            2 => Some(Opcode::Reply),\n", "");
    let r = lint_source("net/fixture.rs", &src);
    assert!(rules_of(&r).contains(&rules::WIRE_CODEC_SYMMETRY), "{}", r.render());
    assert!(
        r.findings.iter().any(|f| f.message.contains("Opcode::Reply")),
        "{}",
        r.render()
    );
}

#[test]
fn wire_codec_symmetry_catches_an_unpaired_codec() {
    let src = R4_CLEAN.replace(
        "pub fn decode_gemm(b: &[u8]) -> usize { b.len() }\n",
        "",
    );
    let r = lint_source("net/fixture.rs", &src);
    // Two findings: encode_gemm unpaired, and the SubmitGemm payload
    // opcode left without a full codec pair.
    assert!(
        r.findings.iter().any(|f| f.message.contains("no matching `decode_gemm`")),
        "{}",
        r.render()
    );
    assert!(
        r.findings.iter().any(|f| f.message.contains("payload opcode `SubmitGemm`")),
        "{}",
        r.render()
    );
}

#[test]
fn wire_codec_symmetry_catches_an_unmatched_error_tag() {
    let src = R4_CLEAN.replace("E::B(m) => (1, m.clone()),", "E::B(m) => (2, m.clone()),");
    let r = lint_source("net/fixture.rs", &src);
    assert!(
        r.findings.iter().any(|f| f.message.contains("error tag 2")),
        "{}",
        r.render()
    );
}

// ---------------------------------------------------------------------------
// R5 no-blocking-ingress
// ---------------------------------------------------------------------------

#[test]
fn blocking_ingress_fires_on_bare_send() {
    let src = "\
fn retire(tx: &std::sync::mpsc::SyncSender<Job>) {
    let _ = tx.send(Job::Retire);
}
enum Job { Retire }
";
    let r = lint_source("coordinator/fixture.rs", src);
    assert_eq!(rules_of(&r), vec![rules::NO_BLOCKING_INGRESS]);
    assert_eq!(r.findings[0].line, 2);
}

#[test]
fn blocking_ingress_permits_try_send_and_test_code() {
    let src = "\
fn retire(tx: &std::sync::mpsc::SyncSender<Job>) {
    let _ = tx.try_send(Job::Retire);
}
enum Job { Retire }
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let (tx, _rx) = std::sync::mpsc::sync_channel(1);
        tx.send(super::Job::Retire).unwrap();
    }
}
";
    let r = lint_source("coordinator/fixture.rs", src);
    assert!(r.is_clean(), "{}", r.render());
}

// ---------------------------------------------------------------------------
// lint:allow semantics
// ---------------------------------------------------------------------------

#[test]
fn justified_allow_suppresses_and_is_counted() {
    let src = "\
fn f(m: &std::sync::Mutex<u8>) -> u8 {
    // lint:allow(no-poison-panic) startup-only: no other thread exists yet
    *m.lock().unwrap()
}
";
    let r = lint_source("fixture.rs", src);
    assert!(r.is_clean(), "{}", r.render());
    assert_eq!(r.suppressions.len(), 1);
    assert_eq!(r.suppressions[0].rule, rules::NO_POISON_PANIC);
    assert!(r.suppressions[0].justification.contains("startup-only"));
    // The exception ledger is printed, not just counted.
    assert!(r.render().contains("allowed [no-poison-panic]"));
}

#[test]
fn unjustified_allow_is_flagged_and_does_not_suppress() {
    let src = "\
fn f(m: &std::sync::Mutex<u8>) -> u8 {
    // lint:allow(no-poison-panic)
    *m.lock().unwrap()
}
";
    let r = lint_source("fixture.rs", src);
    let mut got = rules_of(&r);
    got.sort_unstable();
    assert_eq!(got, vec![rules::ALLOW_JUSTIFICATION, rules::NO_POISON_PANIC]);
    assert!(r.suppressions.is_empty());
}

#[test]
fn stale_allow_is_itself_a_violation() {
    let src = "\
fn f() -> u8 {
    // lint:allow(no-poison-panic) nothing here violates the rule
    7
}
";
    let r = lint_source("fixture.rs", src);
    assert_eq!(rules_of(&r), vec![rules::ALLOW_JUSTIFICATION]);
    assert!(r.findings[0].message.contains("suppresses nothing"));
}

// ---------------------------------------------------------------------------
// The standalone binary: nonzero exit on violations, zero when clean.
// ---------------------------------------------------------------------------

#[test]
fn lint_binary_exit_codes_track_violations() {
    let dir = std::env::temp_dir().join(format!("spoga-lint-fixture-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("create fixture dir");
    let file = dir.join("seeded.rs");

    std::fs::write(
        &file,
        "fn f(m: &std::sync::Mutex<u8>) -> u8 {\n    *m.lock().unwrap()\n}\n",
    )
    .expect("write seeded violation");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_spoga-lint"))
        .arg(&dir)
        .output()
        .expect("run spoga-lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(out.status.code(), Some(1), "stdout:\n{stdout}");
    assert!(stdout.contains(rules::NO_POISON_PANIC), "stdout:\n{stdout}");
    assert!(stdout.contains("1 violation(s)"), "stdout:\n{stdout}");

    std::fs::write(&file, "fn f() -> u8 {\n    7\n}\n").expect("rewrite clean");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_spoga-lint"))
        .arg(&dir)
        .output()
        .expect("run spoga-lint");
    assert!(out.status.success(), "expected clean exit, got {:?}", out.status.code());

    let _ = std::fs::remove_dir_all(&dir);
}
