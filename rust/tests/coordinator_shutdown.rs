//! Integration: coordinator shutdown and fleet failover under in-flight
//! load.
//!
//! Submits a burst from concurrent clients, calls `shutdown()` mid-stream,
//! and asserts that **every** reply slot resolves — either with a result or
//! with a shutdown error — and that the coordinator's threads are joined
//! (no leaks, no panics). The fleet test retires one shard's worker pool
//! mid-burst and asserts that blocking clients fail over to the surviving
//! shard while every reply slot still resolves. Runs against a synthetic
//! manifest so it never skips.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use spoga::coordinator::{
    Coordinator, CoordinatorConfig, Fleet, FleetConfig, Response, RoutePolicy,
};

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("spoga-shutdown-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8\n\
         mlp_b1 m1.hlo.txt i32:1x16 i32:1x4\n\
         mlp_b8 m8.hlo.txt i32:8x16 i32:8x4\n",
    )
    .unwrap();
    dir
}

/// A resolved slot: the receive returned (value or error) without timing
/// out. A `Disconnected` slot only happens in the narrow race where a job
/// entered the queue as the leader exited; it still resolves the caller's
/// wait immediately (the convenience wrappers map it to a coordinator
/// error), so it counts as an error resolution, never a hang.
fn resolve(rx: Response) -> &'static str {
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Ok(_)) => "ok",
        Ok(Err(_)) => "err",
        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => "err",
        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => panic!("reply slot never resolved"),
    }
}

#[test]
fn shutdown_mid_burst_resolves_every_reply_slot() {
    let dir = synthetic_dir("burst");
    let c = Coordinator::start(CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        max_batch_wait_s: 0.004, // a real window so rows are in flight
        ..Default::default()
    })
    .unwrap();
    let h = c.handle();

    // Clients hammer the queue from multiple threads while the main thread
    // shuts the coordinator down mid-stream.
    let clients = 4usize;
    let per_client = 64usize;
    let submitted = Arc::new(AtomicUsize::new(0));
    let rejected = Arc::new(AtomicUsize::new(0));
    let mut joins = Vec::new();
    for cl in 0..clients {
        let h = h.clone();
        let submitted = submitted.clone();
        let rejected = rejected.clone();
        joins.push(std::thread::spawn(move || {
            let mut slots: Vec<Response> = Vec::new();
            for i in 0..per_client {
                let row: Vec<i32> = (0..16).map(|v| ((cl + i + v) % 100) as i32).collect();
                match h.submit_mlp(row) {
                    Ok(rx) => {
                        submitted.fetch_add(1, Ordering::Relaxed);
                        slots.push(rx);
                    }
                    // Submissions racing past shutdown fail fast — also a
                    // resolution, not a hang.
                    Err(_) => {
                        rejected.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            slots
        }));
    }

    // Let part of the burst land, then pull the plug.
    std::thread::sleep(Duration::from_millis(2));
    c.shutdown(); // joins leader, which drains + joins workers

    let mut ok = 0usize;
    let mut err = 0usize;
    for j in joins {
        for rx in j.join().expect("client thread must not panic") {
            match resolve(rx) {
                "ok" => ok += 1,
                _ => err += 1,
            }
        }
    }
    let sub = submitted.load(Ordering::Relaxed);
    let rej = rejected.load(Ordering::Relaxed);
    assert_eq!(ok + err, sub, "every accepted request resolves exactly once");
    assert_eq!(sub + rej, clients * per_client, "every submission accounted for");

    // After shutdown the handle reports a closed coordinator immediately.
    assert!(h.submit_mlp(vec![0; 16]).is_err());
    assert!(h.infer_mlp(vec![0; 16]).is_err());

    // Sanity: the run really was mid-stream (some work completed or failed,
    // and nothing hung to get here).
    let s = h.stats();
    let completed = s.completed.load(Ordering::Relaxed) as usize;
    assert!(completed <= sub);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn repeated_start_shutdown_cycles_are_clean() {
    let dir = synthetic_dir("cycles");
    for cycle in 0..3 {
        let c = Coordinator::start(CoordinatorConfig {
            artifact_dir: dir.to_string_lossy().into_owned(),
            workers: 1,
            max_batch_wait_s: 0.0,
            ..Default::default()
        })
        .unwrap();
        let h = c.handle();
        let out = h.infer_mlp(vec![cycle as i32; 16]).unwrap();
        assert_eq!(out.len(), 4);
        c.shutdown();
        assert!(h.submit_mlp(vec![0; 16]).is_err(), "cycle {cycle} left a live leader");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_fails_over_when_one_shards_workers_die_mid_burst() {
    let dir = synthetic_dir("failover");
    let cfg = CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        max_batch_wait_s: 0.002,
        ..Default::default()
    };
    let fleet = Fleet::start(FleetConfig {
        shards: vec![cfg.clone(), cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();

    // Blocking clients hammer the fleet; they must ALL succeed even though
    // shard 0's worker pool dies mid-burst (the handle retries shard-down
    // errors on the surviving shard).
    let clients = 4usize;
    let per_client = 48usize;
    let mut joins = Vec::new();
    for cl in 0..clients {
        let h = h.clone();
        joins.push(std::thread::spawn(move || {
            for i in 0..per_client {
                let row: Vec<i32> = (0..16).map(|v| ((cl + i + v) % 100) as i32).collect();
                h.infer_mlp(row).expect("fleet must fail over, not fail the request");
            }
        }));
    }

    // Let part of the burst land, then kill shard 0's workers. Its leader
    // stays alive, so queued jobs resolve (with errors once the pool is
    // gone) instead of hanging.
    std::thread::sleep(Duration::from_millis(2));
    h.shard(0).retire_workers().unwrap();

    for j in joins {
        j.join().expect("client thread must not panic");
    }

    // Slot-based submissions aimed straight at the dead shard still
    // resolve — with an error naming the dead pool, never a hang.
    let rx = h.shard(0).submit_mlp(vec![0; 16]).unwrap();
    match rx.recv_timeout(Duration::from_secs(30)) {
        Ok(Err(e)) => assert!(e.to_string().contains("no live workers"), "{e}"),
        Ok(Ok(_)) => panic!("dead shard served a request"),
        Err(e) => panic!("reply slot never resolved: {e}"),
    }

    // The fleet noticed the death (a blocking retry marked it dead, or the
    // probe above would) and still serves through the survivor.
    let out = h.infer_mlp(vec![1; 16]).unwrap();
    assert_eq!(out.len(), 4);
    assert!(h.live_shard_count() >= 1);
    let t = h.telemetry();
    assert_eq!(
        t.completed(),
        t.shards.iter().map(|s| s.completed).sum::<u64>(),
        "rollup stays consistent across failover"
    );

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drop_without_explicit_shutdown_joins_threads() {
    let dir = synthetic_dir("drop");
    let h = {
        let c = Coordinator::start(CoordinatorConfig {
            artifact_dir: dir.to_string_lossy().into_owned(),
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        let h = c.handle();
        h.infer_mlp(vec![1; 16]).unwrap();
        h
        // `c` drops here: Drop sends Shutdown and joins the leader.
    };
    assert!(h.submit_mlp(vec![0; 16]).is_err());
    let _ = std::fs::remove_dir_all(&dir);
}
