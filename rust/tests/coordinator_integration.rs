//! Integration: the coordinator under concurrent load.

use std::sync::atomic::Ordering;

use spoga::coordinator::{Coordinator, CoordinatorConfig};
use spoga::runtime::Engine;

fn cfg() -> CoordinatorConfig {
    CoordinatorConfig { workers: 2, max_batch_wait_s: 0.005, ..Default::default() }
}

fn available() -> bool {
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        true
    } else {
        eprintln!("SKIP (run `make artifacts` first)");
        false
    }
}

#[test]
fn concurrent_mlp_requests_all_complete_and_match_direct_engine() {
    if !available() {
        return;
    }
    let c = Coordinator::start(cfg()).unwrap();
    let h = c.handle();

    // Ground truth from a direct engine (no coordinator).
    let mut eng = Engine::new("artifacts").unwrap();
    let rows: Vec<Vec<i32>> =
        (0..12).map(|t| vec![(t * 9 % 100) as i32; 784]).collect();
    let expected: Vec<Vec<i32>> =
        rows.iter().map(|r| eng.execute_i32_single("mlp_b1", &[r]).unwrap()).collect();

    let joins: Vec<_> = rows
        .iter()
        .cloned()
        .map(|row| {
            let h = h.clone();
            std::thread::spawn(move || h.infer_mlp(row).unwrap())
        })
        .collect();
    let got: Vec<Vec<i32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();

    for (g, e) in got.iter().zip(&expected) {
        assert_eq!(g, e, "batched answer differs from direct execution");
    }
    assert_eq!(h.stats().completed.load(Ordering::Relaxed), 12);
    assert_eq!(h.stats().failed.load(Ordering::Relaxed), 0);
    c.shutdown();
}

#[test]
fn burst_load_forms_multi_row_batches() {
    if !available() {
        return;
    }
    let c = Coordinator::start(CoordinatorConfig {
        workers: 1,
        max_batch_wait_s: 0.05, // generous window to observe batching
        ..Default::default()
    })
    .unwrap();
    let h = c.handle();
    let joins: Vec<_> = (0..16)
        .map(|i| {
            let h = h.clone();
            std::thread::spawn(move || h.infer_mlp(vec![i as i32; 784]).unwrap())
        })
        .collect();
    for j in joins {
        assert_eq!(j.join().unwrap().len(), 10);
    }
    let occupancy = h.stats().mean_batch_occupancy();
    assert!(occupancy > 1.0, "burst produced no batching (occupancy {occupancy})");
    c.shutdown();
}

#[test]
fn gemm_requests_route_unbatched() {
    if !available() {
        return;
    }
    let c = Coordinator::start(cfg()).unwrap();
    let h = c.handle();
    let a = vec![1i32; 64 * 64];
    let b = vec![2i32; 64 * 64];
    let out = h.gemm("gemm_64x64x64", a, b).unwrap();
    assert_eq!(out, vec![2 * 64; 64 * 64]);
    c.shutdown();
}

#[test]
fn unknown_artifact_fails_cleanly() {
    if !available() {
        return;
    }
    let c = Coordinator::start(cfg()).unwrap();
    let h = c.handle();
    let res = h.gemm("gemm_wrong", vec![0; 4], vec![0; 4]);
    assert!(res.is_err());
    // Coordinator still serves afterwards.
    assert_eq!(h.infer_mlp(vec![0; 784]).unwrap(), vec![0; 10]);
    c.shutdown();
}

#[test]
fn wrong_row_length_rejected_at_submit() {
    if !available() {
        return;
    }
    let c = Coordinator::start(cfg()).unwrap();
    let h = c.handle();
    assert!(h.submit_mlp(vec![0; 42]).is_err());
    c.shutdown();
}

#[test]
fn shutdown_then_submit_errors() {
    if !available() {
        return;
    }
    let c = Coordinator::start(cfg()).unwrap();
    let h = c.handle();
    c.shutdown();
    // The leader is gone; submissions must fail, not hang.
    let r = h.infer_mlp(vec![0; 784]);
    assert!(r.is_err());
}

#[test]
fn stats_latency_recorded() {
    if !available() {
        return;
    }
    let c = Coordinator::start(cfg()).unwrap();
    let h = c.handle();
    h.infer_mlp(vec![1; 784]).unwrap();
    assert!(h.stats().latency_mean() > 0.0);
    assert!(h.stats().latency_percentile(0.5) > 0.0);
    c.shutdown();
}
