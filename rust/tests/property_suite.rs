//! Property-based test suite over the crate's invariants, driven by the
//! in-tree mini property harness (`spoga::testing`).

use spoga::bitslice::{
    combine, gemm_i16_lanes_naive, gemm_i16_lanes_tiled, gemm_i32, gemm_i32_naive,
    gemm_i32_tiled, gemm_lanes, gemm_lanes_naive, gemm_lanes_tiled, gemm_sliced,
    gemm_sliced_naive, gemm_sliced_tiled, slice_i8, MicroKernel, TileConfig,
};
use spoga::dnn::layer::GemmShape;
use spoga::optics::link_budget::{ArchClass, LinkBudget};
use spoga::testing::prop::GemmCase;
use spoga::testing::{forall, SplitMix64};
use spoga::units::{db_to_ratio, dbm_to_mw, mw_to_dbm, ratio_to_db, DataRate};

// ---------------------------------------------------------------------------
// bitslice
// ---------------------------------------------------------------------------

#[test]
fn prop_nibble_roundtrip() {
    forall(11, 2000, |rng: &mut SplitMix64| rng.i8(), |&x| combine(slice_i8(x)) == x);
}

#[test]
fn prop_three_dataflows_agree() {
    forall(17, 80, GemmCase { max_dim: 14 }, |(a, b, m, k, n)| {
        let direct = gemm_i32(a, b, *m, *k, *n).unwrap();
        let sliced = gemm_sliced(a, b, *m, *k, *n).unwrap().recombine();
        let lanes = gemm_lanes(a, b, *m, *k, *n).unwrap().weight_and_add();
        direct == sliced && direct == lanes
    });
}

#[test]
fn prop_gemm_linearity_in_scalar() {
    // (2a)·b == 2·(a·b) when 2a stays in int8 range.
    forall(23, 60, GemmCase { max_dim: 8 }, |(a, b, m, k, n)| {
        let a_half: Vec<i8> = a.iter().map(|&v| v / 2).collect();
        let doubled: Vec<i8> = a_half.iter().map(|&v| v * 2).collect();
        let lhs = gemm_i32(&doubled, b, *m, *k, *n).unwrap();
        let rhs: Vec<i32> =
            gemm_i32(&a_half, b, *m, *k, *n).unwrap().iter().map(|v| 2 * v).collect();
        lhs == rhs
    });
}

#[test]
fn prop_gemm_distributes_over_split_k() {
    // A·B over K splits into A1·B1 + A2·B2 (charge accumulation across
    // passes — the BPCA multi-pass invariant).
    forall(31, 50, GemmCase { max_dim: 10 }, |(a, b, m, k, n)| {
        if *k < 2 {
            return true;
        }
        let k1 = k / 2;
        let a1: Vec<i8> = (0..*m).flat_map(|i| a[i * k..i * k + k1].to_vec()).collect();
        let a2: Vec<i8> = (0..*m).flat_map(|i| a[i * k + k1..(i + 1) * k].to_vec()).collect();
        let b1 = b[..k1 * n].to_vec();
        let b2 = b[k1 * n..].to_vec();
        let full = gemm_i32(a, b, *m, *k, *n).unwrap();
        let p1 = gemm_i32(&a1, &b1, *m, k1, *n).unwrap();
        let p2 = gemm_i32(&a2, &b2, *m, k - k1, *n).unwrap();
        let sum: Vec<i32> = p1.iter().zip(&p2).map(|(x, y)| x + y).collect();
        full == sum
    });
}

// ---------------------------------------------------------------------------
// bitslice packed/tiled/threaded kernels vs the naive oracles
// ---------------------------------------------------------------------------

/// Tile configs that force partial k/j blocks and multi-band threading even
/// on the small shapes the generator produces (non-tile-multiple on purpose).
/// Scalar, Simd and Avx2 micro-kernels are all represented so every property
/// in this file cross-checks the register-blocked paths against the scalar
/// one (Avx2 resolves to Simd on hosts without the feature, so those rows
/// are valid everywhere; wide-shape 16-block coverage lives in the kernel
/// unit tests since the generator's dims stay under AVX2_BLOCK_W).
fn oracle_stress_cfgs() -> Vec<TileConfig> {
    vec![
        TileConfig { kc: 1, jc: 1, threads: 1, micro: MicroKernel::Scalar },
        TileConfig { kc: 3, jc: 2, threads: 2, micro: MicroKernel::Simd },
        TileConfig { kc: 5, jc: 7, threads: 4, micro: MicroKernel::Scalar },
        TileConfig { kc: 5, jc: 7, threads: 4, micro: MicroKernel::Simd },
        TileConfig { kc: 5, jc: 7, threads: 4, micro: MicroKernel::Avx2 },
        TileConfig { kc: 4096, jc: 4096, threads: 3, micro: MicroKernel::Scalar },
        TileConfig { kc: 4096, jc: 4096, threads: 3, micro: MicroKernel::Simd },
        TileConfig { kc: 4096, jc: 4096, threads: 3, micro: MicroKernel::Avx2 },
    ]
}

#[test]
fn prop_packed_kernels_bit_exact_vs_naive_oracles() {
    forall(83, 30, GemmCase { max_dim: 15 }, |(a, b, m, k, n)| {
        let i32_oracle = gemm_i32_naive(a, b, *m, *k, *n).unwrap();
        let lanes_oracle = gemm_lanes_naive(a, b, *m, *k, *n).unwrap();
        let sliced_oracle = gemm_sliced_naive(a, b, *m, *k, *n).unwrap();
        oracle_stress_cfgs().iter().all(|cfg| {
            let ci = gemm_i32_tiled(a, b, *m, *k, *n, cfg).unwrap();
            let cl = gemm_lanes_tiled(a, b, *m, *k, *n, cfg).unwrap();
            let cs = gemm_sliced_tiled(a, b, *m, *k, *n, cfg).unwrap();
            ci == i32_oracle
                && cl.hi == lanes_oracle.hi
                && cl.mid == lanes_oracle.mid
                && cl.lo == lanes_oracle.lo
                && cs.mm == sliced_oracle.mm
                && cs.ml == sliced_oracle.ml
                && cs.lm == sliced_oracle.lm
                && cs.ll == sliced_oracle.ll
        })
    });
}

#[test]
fn prop_packed_kernels_handle_extreme_operands() {
    // Operand matrices drawn only from {-128, 127, 0, -1}: the signed-MSN
    // and unsigned-LSN corners of the nibble decomposition.
    forall(
        89,
        30,
        |rng: &mut SplitMix64| {
            let m = rng.range_usize(1, 9);
            let k = rng.range_usize(1, 11);
            let n = rng.range_usize(1, 9);
            let corners = [-128i8, 127, 0, -1];
            let a: Vec<i8> = (0..m * k).map(|_| *rng.choose(&corners)).collect();
            let b: Vec<i8> = (0..k * n).map(|_| *rng.choose(&corners)).collect();
            (a, b, m, k, n)
        },
        |(a, b, m, k, n)| {
            let oracle = gemm_lanes_naive(a, b, *m, *k, *n).unwrap();
            oracle_stress_cfgs().iter().all(|cfg| {
                let fast = gemm_lanes_tiled(a, b, *m, *k, *n, cfg).unwrap();
                fast.hi == oracle.hi && fast.mid == oracle.mid && fast.lo == oracle.lo
            })
        },
    );
}

#[test]
fn prop_wide_packed_kernel_bit_exact_vs_naive_oracle() {
    forall(
        97,
        12,
        |rng: &mut SplitMix64| {
            let m = rng.range_usize(1, 7);
            let k = rng.range_usize(1, 9);
            let n = rng.range_usize(1, 7);
            let a: Vec<i16> = (0..m * k).map(|_| rng.next_u64() as i16).collect();
            let b: Vec<i16> = (0..k * n).map(|_| rng.next_u64() as i16).collect();
            (a, b, m, k, n)
        },
        |(a, b, m, k, n)| {
            let oracle = gemm_i16_lanes_naive(a, b, *m, *k, *n).unwrap();
            oracle_stress_cfgs().iter().all(|cfg| {
                gemm_i16_lanes_tiled(a, b, *m, *k, *n, cfg).unwrap().lanes == oracle.lanes
            })
        },
    );
}

#[test]
fn prop_public_dispatchers_always_match_oracles() {
    // Shapes straddling the dispatch threshold: the public entry points must
    // be bit-exact with the oracles regardless of which kernel served them.
    forall(
        101,
        8,
        |rng: &mut SplitMix64| {
            let m = rng.range_usize(1, 40);
            let k = rng.range_usize(1, 40);
            let n = rng.range_usize(1, 40);
            (rng.i8_vec(m * k), rng.i8_vec(k * n), m, k, n)
        },
        |(a, b, m, k, n)| {
            let direct = gemm_i32(a, b, *m, *k, *n).unwrap();
            direct == gemm_i32_naive(a, b, *m, *k, *n).unwrap()
                && gemm_lanes(a, b, *m, *k, *n).unwrap().weight_and_add() == direct
                && gemm_sliced(a, b, *m, *k, *n).unwrap().recombine() == direct
        },
    );
}

// ---------------------------------------------------------------------------
// optics
// ---------------------------------------------------------------------------

#[test]
fn prop_units_roundtrip() {
    forall(
        41,
        2000,
        |rng: &mut SplitMix64| rng.f64_range(-60.0, 30.0),
        |&dbm| (mw_to_dbm(dbm_to_mw(dbm)) - dbm).abs() < 1e-9,
    );
    forall(
        43,
        2000,
        |rng: &mut SplitMix64| rng.f64_range(-30.0, 30.0),
        |&db| (ratio_to_db(db_to_ratio(db)) - db).abs() < 1e-9,
    );
}

#[test]
fn prop_max_n_is_tight() {
    // The solver's N is feasible and N+1 is not, for random laser powers.
    forall(
        53,
        200,
        |rng: &mut SplitMix64| {
            let arch = *rng.choose(&[ArchClass::Maw, ArchClass::Amw, ArchClass::Mwa]);
            let dr = *rng.choose(&DataRate::ALL);
            let dbm = rng.f64_range(-5.0, 20.0);
            (arch, dr, dbm)
        },
        |&(arch, dr, dbm)| {
            let lb = LinkBudget::for_arch(arch);
            let m = lb.m_cap.unwrap_or(16);
            let n = lb.max_n_given_m(m, dr, dbm);
            let ok_n = n == 0 || lb.feasible(n, m, dr, dbm);
            let cap = lb.n_cap.unwrap_or(usize::MAX);
            let tight = n >= cap || !lb.feasible(n + 1, m, dr, dbm);
            ok_n && tight
        },
    );
}

#[test]
fn prop_budget_monotone_in_power_and_rate() {
    forall(
        59,
        200,
        |rng: &mut SplitMix64| {
            let arch = *rng.choose(&[ArchClass::Maw, ArchClass::Amw, ArchClass::Mwa]);
            let dbm = rng.f64_range(-5.0, 18.0);
            (arch, dbm)
        },
        |&(arch, dbm)| {
            let lb = LinkBudget::for_arch(arch);
            let m = lb.m_cap.unwrap_or(8);
            let n_lo = lb.max_n_given_m(m, DataRate::Gs10, dbm);
            let n_mid = lb.max_n_given_m(m, DataRate::Gs5, dbm);
            let n_hi = lb.max_n_given_m(m, DataRate::Gs1, dbm);
            let n_more_power = lb.max_n_given_m(m, DataRate::Gs5, dbm + 1.0);
            n_lo <= n_mid && n_mid <= n_hi && n_more_power >= n_mid
        },
    );
}

// ---------------------------------------------------------------------------
// arch / sim
// ---------------------------------------------------------------------------

#[test]
fn prop_plan_timesteps_monotone_in_shape() {
    use spoga::arch::core::Core;
    let spoga = Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap();
    let holy = Core::design(ArchClass::Maw, DataRate::Gs5, 10.0).unwrap();
    forall(
        61,
        300,
        |rng: &mut SplitMix64| GemmShape {
            t: rng.range_usize(1, 512),
            k: rng.range_usize(1, 2048),
            c: rng.range_usize(1, 512),
            groups: rng.range_usize(1, 4),
        },
        |s| {
            for core in [&spoga, &holy] {
                let p = core.plan_gemm(s);
                let bigger = GemmShape { t: s.t + 7, k: s.k + 50, c: s.c + 9, groups: s.groups };
                let pb = core.plan_gemm(&bigger);
                if pb.timesteps < p.timesteps || p.timesteps == 0 {
                    return false;
                }
                // SPOGA never converts more than once per output.
                if core.arch == ArchClass::Mwa && p.adc_conversions != s.outputs() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn prop_energy_positive_and_additive() {
    use spoga::arch::core::Core;
    use spoga::arch::cost::EnergyBreakdown;
    let core = Core::design(ArchClass::Amw, DataRate::Gs10, 10.0).unwrap();
    forall(
        67,
        200,
        |rng: &mut SplitMix64| GemmShape {
            t: rng.range_usize(1, 256),
            k: rng.range_usize(1, 1024),
            c: rng.range_usize(1, 256),
            groups: 1,
        },
        |s| {
            let plan = core.plan_gemm(s);
            let e = EnergyBreakdown::of_plan(&core, &plan);
            let mut acc = EnergyBreakdown::default();
            acc.add(&e);
            acc.add(&e);
            e.total_j() > 0.0 && (acc.total_j() - 2.0 * e.total_j()).abs() < 1e-12
        },
    );
}

#[test]
fn prop_fleet_scaling_never_hurts_fps() {
    use spoga::arch::accel::Accelerator;
    use spoga::arch::core::Core;
    use spoga::dnn::models::shufflenet_v2;
    use spoga::sim::engine::simulate_frame;
    let w = shufflenet_v2().workload();
    forall(
        71,
        20,
        |rng: &mut SplitMix64| rng.range_usize(1, 64),
        |&cores| {
            let core = Core::design(ArchClass::Mwa, DataRate::Gs5, 10.0).unwrap();
            let f1 = simulate_frame(&Accelerator::with_cores(core.clone(), cores), &w);
            let f2 = simulate_frame(&Accelerator::with_cores(core, cores * 2), &w);
            f2.fps() >= f1.fps()
        },
    );
}

// ---------------------------------------------------------------------------
// runtime manifest
// ---------------------------------------------------------------------------

#[test]
fn prop_manifest_roundtrip() {
    use spoga::runtime::Manifest;
    forall(
        73,
        100,
        |rng: &mut SplitMix64| {
            let n = rng.range_usize(1, 6);
            (0..n)
                .map(|i| {
                    let d1 = rng.range_usize(1, 512);
                    let d2 = rng.range_usize(1, 512);
                    format!("art{i} art{i}.hlo.txt i32:{d1}x{d2} i32:{d1}x{d2}")
                })
                .collect::<Vec<_>>()
                .join("\n")
        },
        |text| {
            let m = Manifest::parse(text, std::path::PathBuf::from("/tmp")).unwrap();
            m.artifacts.len() == text.lines().count()
                && m.artifacts.iter().all(|a| {
                    a.inputs[0].elements() == a.outputs[0].elements()
                        && m.get(&a.name).is_ok()
                })
        },
    );
}

// ---------------------------------------------------------------------------
// coordinator stats
// ---------------------------------------------------------------------------

#[test]
fn prop_latency_percentiles_monotone() {
    use spoga::coordinator::CoordinatorStats;
    forall(
        79,
        50,
        |rng: &mut SplitMix64| {
            (0..rng.range_usize(1, 200))
                .map(|_| rng.f64_range(1e-6, 2.0))
                .collect::<Vec<f64>>()
        },
        |lats| {
            let s = CoordinatorStats::default();
            for &l in lats {
                s.record_latency(l);
            }
            let p10 = s.latency_percentile(0.1);
            let p50 = s.latency_percentile(0.5);
            let p99 = s.latency_percentile(0.99);
            p10 <= p50 && p50 <= p99 && p99 > 0.0
        },
    );
}
