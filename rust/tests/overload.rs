//! Overload suite: typed shedding, admission QoS, and deadline-aware
//! batching under real saturation.
//!
//! Pins this PR's acceptance contract (the full-queue ingress deadlock
//! fix), all against synthetic manifests so nothing ever skips:
//!
//! * a saturated shard (1 worker, 1-slot ingress, heavy frames) *sheds*
//!   excess submissions with typed `Error::Overloaded` — no submitting
//!   thread ever blocks past a bound, every refused payload comes back
//!   intact, the shed counters equal the refusals the clients observed,
//!   and every *accepted* request still resolves;
//! * shedding is busy-not-dead at the fleet tier: a shard refusing load is
//!   never retired, and the fleet telemetry rollup sums shed counters
//!   across shards;
//! * a mixed-priority burst holds High (all served) while BestEffort
//!   sheds at the admission watermark;
//! * an already-expired job fails typed (`Error::DeadlineExceeded`)
//!   before any worker execute; a job with a tight deadline inside a long
//!   batching window is flushed *early* and served instead of waiting the
//!   window out.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use spoga::coordinator::{
    Coordinator, CoordinatorConfig, Fleet, FleetConfig, Qos, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::runtime::BackendKind;
use spoga::Error;

const MANIFEST: &str = "\
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-overload-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn shard_cfg(dir: &PathBuf) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        backend: BackendKind::Software,
        ..Default::default()
    }
}

/// A CNN heavy enough (~5 MMACs of nibble-sliced conv per frame) that one
/// worker takes real wall-clock per frame — the saturation tests rely on
/// the drain rate being far below a tight submission loop's rate.
fn heavy_cnn() -> CnnModel {
    CnnModel {
        name: "heavy_overload",
        layers: vec![
            Layer::conv("stem", 32, 32, 16, 32, 3, 1, 1),
            Layer::fc("head", 32 * 32 * 32, 8),
        ],
    }
}

fn heavy_input(tag: i32) -> Vec<i32> {
    (0..32 * 32 * 16).map(|v| ((v as i32 * 17 + tag * 71) % 251) - 125).collect()
}

/// The headline acceptance test: a saturated shard (1 worker, 1-slot
/// ingress, `max_cnn_batch: 1` so every frame dispatches immediately into
/// the bounded worker queue) refuses excess load typed instead of parking
/// submitter threads. Asserts, end to end: no submit call blocks past a
/// bound, each refusal is `Error::Overloaded` with the payload recovered
/// intact, the shard's `shed` counter equals the refusals the submitters
/// observed, sheds never enter `requests` (queue depth stays truthful),
/// and every accepted frame still resolves.
#[test]
fn saturated_shard_sheds_typed_and_never_blocks_submitters() {
    let dir = synthetic_dir("saturate");
    let c = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_depth: 1,
        max_cnn_batch: 1,
        ..shard_cfg(&dir)
    })
    .unwrap();
    let h = c.handle();
    let model = heavy_cnn();

    let threads = 4usize;
    let per_thread = 8usize;
    let joins: Vec<_> = (0..threads)
        .map(|t| {
            let h = h.clone();
            let model = model.clone();
            std::thread::spawn(move || {
                let mut slots = Vec::new();
                let mut shed = 0u64;
                for i in 0..per_thread {
                    let tag = (t * per_thread + i) as i32;
                    let input = heavy_input(tag);
                    let before = Instant::now();
                    match h.try_submit_cnn(model.clone(), input.clone()) {
                        Ok(rx) => slots.push(rx),
                        Err(rejected) => {
                            assert!(
                                matches!(rejected.error, Error::Overloaded(_)),
                                "only typed overload may refuse a live shard: {}",
                                rejected.error
                            );
                            let (m, recovered) = rejected.payload;
                            assert_eq!(m.name, "heavy_overload");
                            assert_eq!(recovered, input, "payload must come back intact");
                            shed += 1;
                        }
                    }
                    // Non-blocking admission: even under full saturation a
                    // submit call is one `try_send`, never a park. The bound
                    // is generous to be unflakeable — the pre-fix behaviour
                    // blocked indefinitely.
                    assert!(
                        before.elapsed() < Duration::from_secs(5),
                        "submitter blocked on a saturated ingress queue"
                    );
                }
                // Accepted work resolves even though the shard was slammed.
                for rx in slots {
                    rx.recv_timeout(Duration::from_secs(120))
                        .expect("response slot must resolve")
                        .expect("accepted frame must serve");
                }
                shed
            })
        })
        .collect();
    let observed_sheds: u64 = joins.into_iter().map(|j| j.join().unwrap()).sum();

    let stats = h.stats();
    assert!(
        observed_sheds > 0,
        "the burst never saturated the 1-slot ingress — the overload path was not exercised"
    );
    assert_eq!(
        stats.shed.load(Ordering::Relaxed),
        observed_sheds,
        "every shed is counted exactly once"
    );
    assert_eq!(stats.shed_best_effort.load(Ordering::Relaxed), 0, "burst was all High");
    let accepted = (threads * per_thread) as u64 - observed_sheds;
    assert_eq!(
        stats.requests.load(Ordering::Relaxed),
        accepted,
        "sheds must never enter the accepted-request counter"
    );
    assert_eq!(stats.completed.load(Ordering::Relaxed), accepted);
    assert_eq!(stats.queue_depth(), 0, "depth must drain to zero — no leaked slots");
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Busy-not-dead at the fleet tier: both shards of a fleet shed every
/// best-effort submission (watermark 0), the fleet reports terminal
/// `Overloaded` after bouncing across the live set, *neither shard is
/// retired*, and the telemetry rollup sums shed counters across shards.
/// High-priority traffic keeps serving throughout.
#[test]
fn overloaded_fleet_stays_live_and_rolls_up_shed_counters() {
    let dir = synthetic_dir("busy-not-dead");
    let cfg = CoordinatorConfig { best_effort_watermark: Some(0), ..shard_cfg(&dir) };
    let fleet = Fleet::start(FleetConfig {
        shards: vec![cfg.clone(), cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();

    let attempts = 6u64;
    for i in 0..attempts {
        let err = h
            .submit_mlp_qos(vec![i as i32; 16], Qos::best_effort())
            .expect_err("watermark 0 sheds every best-effort submission on every shard");
        assert!(matches!(err, Error::Overloaded(_)), "{err}");
    }
    // Shedding is busy, not dead: nothing left the rotation, nothing was
    // counted as a failover.
    assert_eq!(h.live_shard_count(), 2, "an overloaded shard must never be retired");
    let t = h.telemetry();
    assert_eq!(t.submit_reroutes, 0, "overload bounces are not dead-shard reroutes");
    // Each refused attempt bounced across both live shards: 2 sheds per
    // attempt, summed by the rollup.
    assert_eq!(t.shed(), 2 * attempts);
    assert_eq!(t.shed_best_effort(), 2 * attempts);
    assert_eq!(t.shards[0].shed + t.shards[1].shed, 2 * attempts);
    assert!(t.summary().contains("qos(shed="), "rollup summary must surface QoS sheds");

    // High priority is untouched by the watermark and still serves.
    let out = h.infer_mlp(vec![3; 16]).expect("high-priority traffic must keep serving");
    assert_eq!(out.len(), 4);
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Mixed-priority burst against one watermarked shard: every High request
/// is served (the watermark never applies to it, and the deep default
/// queue never fills), every BestEffort submission sheds typed, with the
/// attribution counters split exactly.
#[test]
fn mixed_priority_burst_holds_high_while_best_effort_sheds() {
    let dir = synthetic_dir("mixed");
    let fleet = Fleet::single(CoordinatorConfig {
        best_effort_watermark: Some(0),
        ..shard_cfg(&dir)
    })
    .unwrap();
    let h = fleet.handle();

    let per_class = 8usize;
    let joins: Vec<_> = (0..4usize)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                let (mut high_ok, mut be_shed) = (0u64, 0u64);
                for i in 0..per_class {
                    let row = vec![((t * per_class + i) % 50) as i32; 16];
                    if t % 2 == 0 {
                        let out = h.infer_mlp(row).expect("High must be held");
                        assert_eq!(out.len(), 4);
                        high_ok += 1;
                    } else {
                        match h.submit_mlp_qos(row, Qos::best_effort()) {
                            Err(Error::Overloaded(_)) => be_shed += 1,
                            Err(e) => panic!("unexpected refusal: {e}"),
                            Ok(_) => panic!("watermark 0 must shed every best-effort row"),
                        }
                    }
                }
                (high_ok, be_shed)
            })
        })
        .collect();
    let (mut high_ok, mut be_shed) = (0u64, 0u64);
    for j in joins {
        let (hi, be) = j.join().unwrap();
        high_ok += hi;
        be_shed += be;
    }
    assert_eq!(high_ok, 2 * per_class as u64);
    assert_eq!(be_shed, 2 * per_class as u64);
    let stats = h.shard_stats(0);
    assert_eq!(stats.shed.load(Ordering::Relaxed), be_shed);
    assert_eq!(stats.shed_best_effort.load(Ordering::Relaxed), be_shed);
    assert_eq!(stats.completed.load(Ordering::Relaxed), high_ok);
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Deadline semantics, both halves:
///
/// * an already-expired job (deadline zero) fails typed with
///   `Error::DeadlineExceeded` before any worker execute — `completed`
///   stays zero, the expiry is attributed, and the stats invariant closes
///   (`failed` absorbs it, depth drains);
/// * a tight-deadline job gathered inside a *long* batching window is
///   flushed early and served — it does not wait the window out (which
///   would miss the deadline), and it resolves far sooner than the window.
#[test]
fn deadlines_fail_typed_before_execute_and_flush_windows_early() {
    let dir = synthetic_dir("deadline");
    // Long window so the only way a deadline job serves in time is the
    // early flush; 1 worker keeps the execution order deterministic.
    let c = Coordinator::start(CoordinatorConfig {
        workers: 1,
        max_batch_wait_s: 20.0,
        ..shard_cfg(&dir)
    })
    .unwrap();
    let h = c.handle();

    // Half 1: born expired. The leader reaps it at the gather step; no
    // worker ever sees it.
    let rx = h
        .submit_mlp_qos(vec![1; 16], Qos::default().with_deadline(Duration::ZERO))
        .expect("admission accepts; expiry is judged at the leader");
    let err = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("expired job must still resolve its slot")
        .expect_err("a born-expired job must not serve");
    assert!(matches!(err, Error::DeadlineExceeded(_)), "{err}");
    let stats = h.stats();
    assert_eq!(stats.deadline_expired.load(Ordering::Relaxed), 1);
    assert_eq!(stats.completed.load(Ordering::Relaxed), 0, "no worker execute was burned");
    assert_eq!(stats.failed.load(Ordering::Relaxed), 1);
    assert_eq!(stats.queue_depth(), 0);

    // Half 2: tight deadline inside the 20 s window → early flush serves it.
    let t0 = Instant::now();
    let rx = h
        .submit_mlp_qos(vec![2; 16], Qos::default().with_deadline(Duration::from_secs(2)))
        .expect("accepted");
    let reply = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("slot resolves")
        .expect("a meetable deadline must be met, not reaped");
    assert_eq!(reply.outputs.len(), 4);
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "served after {:?} — the window was waited out instead of flushing early",
        t0.elapsed()
    );
    assert_eq!(stats.deadline_expired.load(Ordering::Relaxed), 1, "no new expiry");
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// QoS payload recovery composes with the watermark: a best-effort
/// submission refused at admission hands its payload back through the
/// public `try_submit_*_qos` surface, exactly like a full queue does.
#[test]
fn best_effort_shed_recovers_the_payload() {
    let dir = synthetic_dir("recover-qos");
    let c = Coordinator::start(CoordinatorConfig {
        best_effort_watermark: Some(0),
        ..shard_cfg(&dir)
    })
    .unwrap();
    let h = c.handle();

    let row = vec![9i32; 16];
    let rejected = h
        .try_submit_mlp_qos(row.clone(), Qos::best_effort())
        .expect_err("watermark 0 sheds every best-effort row");
    assert!(matches!(rejected.error, Error::Overloaded(_)), "{}", rejected.error);
    assert_eq!(rejected.payload, row, "payload must come back intact");

    let model = heavy_cnn();
    let input = heavy_input(0);
    let rejected = h
        .try_submit_cnn_qos(model.clone(), input.clone(), Qos::best_effort())
        .expect_err("watermark 0 sheds the CNN path too");
    assert!(matches!(rejected.error, Error::Overloaded(_)));
    assert_eq!(rejected.payload.0, model);
    assert_eq!(rejected.payload.1, input);

    let (a, b) = (vec![1i32; 4], vec![2i32; 4]);
    let rejected = h
        .try_submit_gemm_qos("g", a.clone(), b.clone(), Qos::best_effort())
        .expect_err("watermark 0 sheds the GEMM path too");
    assert!(matches!(rejected.error, Error::Overloaded(_)));
    assert_eq!(rejected.payload, (a, b));

    // Nothing was accepted, nothing leaked.
    assert_eq!(h.stats().requests.load(Ordering::Relaxed), 0);
    assert_eq!(h.stats().shed.load(Ordering::Relaxed), 3);
    assert_eq!(h.stats().shed_best_effort.load(Ordering::Relaxed), 3);
    c.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
