//! Integration: AOT artifacts executed through PJRT must match the rust
//! bitslice golden model bit-for-bit (three-layer composition proof).
//!
//! Requires `make artifacts`; tests skip (with a loud message) if the
//! artifact directory is missing so `cargo test` still works standalone.

use spoga::bitslice;
use spoga::runtime::Engine;
use spoga::testing::SplitMix64;

fn engine() -> Option<Engine> {
    match Engine::new("artifacts") {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts` first): {e}");
            None
        }
    }
}

fn rand_wire_i8(rng: &mut SplitMix64, len: usize) -> Vec<i32> {
    (0..len).map(|_| rng.i8() as i32).collect()
}

#[test]
fn every_gemm_artifact_matches_golden_model() {
    let Some(mut eng) = engine() else { return };
    let names: Vec<String> = eng
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.name.starts_with("gemm_"))
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty(), "no gemm artifacts in manifest");
    let mut rng = SplitMix64::new(0xC0FFEE);
    for name in names {
        let meta = eng.manifest().get(&name).unwrap().clone();
        let (m, k) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
        let n = meta.inputs[1].dims[1];
        let a = rand_wire_i8(&mut rng, m * k);
        let b = rand_wire_i8(&mut rng, k * n);
        let out = eng.execute_i32_single(&name, &[&a, &b]).unwrap();
        let a8: Vec<i8> = a.iter().map(|&v| v as i8).collect();
        let b8: Vec<i8> = b.iter().map(|&v| v as i8).collect();
        let golden = bitslice::gemm_i32(&a8, &b8, m, k, n).unwrap();
        assert_eq!(out, golden, "{name} disagrees with golden model");
    }
}

#[test]
fn mlp_batch_variants_agree_row_for_row() {
    let Some(mut eng) = engine() else { return };
    let mut rng = SplitMix64::new(42);
    let row: Vec<i32> = (0..784).map(|_| (rng.below(128)) as i32).collect();

    let b1 = eng.execute_i32_single("mlp_b1", &[&row]).unwrap();

    // Same row in slot 0 (rest zero-padded) of the b8 and b32 variants.
    for (name, b) in [("mlp_b8", 8usize), ("mlp_b32", 32usize)] {
        let mut padded = vec![0i32; b * 784];
        padded[..784].copy_from_slice(&row);
        let out = eng.execute_i32_single(name, &[&padded]).unwrap();
        assert_eq!(out.len(), b * 10);
        assert_eq!(&out[..10], &b1[..], "{name} row 0 != mlp_b1");
    }
}

#[test]
fn mlp_is_deterministic_across_engines() {
    let Some(mut e1) = engine() else { return };
    let mut e2 = Engine::new("artifacts").unwrap();
    let row = vec![7i32; 784];
    let a = e1.execute_i32_single("mlp_b1", &[&row]).unwrap();
    let b = e2.execute_i32_single("mlp_b1", &[&row]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn cnn_zero_input_gives_zero_logits() {
    let Some(mut eng) = engine() else { return };
    let x = vec![0i32; 28 * 28];
    let out = eng.execute_i32_single("cnn_b1", &[&x]).unwrap();
    assert_eq!(out, vec![0i32; 10]);
}

#[test]
fn cnn_batch_variant_consistent() {
    let Some(mut eng) = engine() else { return };
    let mut rng = SplitMix64::new(7);
    let img: Vec<i32> = (0..28 * 28).map(|_| rng.below(128) as i32).collect();
    let b1 = eng.execute_i32_single("cnn_b1", &[&img]).unwrap();
    let mut batch = vec![0i32; 8 * 28 * 28];
    batch[..784].copy_from_slice(&img);
    let b8 = eng.execute_i32_single("cnn_b8", &[&batch]).unwrap();
    assert_eq!(&b8[..10], &b1[..]);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let Some(mut eng) = engine() else { return };
    let short = vec![0i32; 10];
    assert!(eng.execute_i32_single("mlp_b1", &[&short]).is_err());
    let row = vec![0i32; 784];
    assert!(eng.execute_i32_single("mlp_b1", &[&row, &row]).is_err());
    assert!(eng.execute_i32_single("no_such_artifact", &[&row]).is_err());
}

#[test]
fn manifest_covers_expected_artifact_families() {
    let Some(eng) = engine() else { return };
    let names: Vec<&str> =
        eng.manifest().artifacts.iter().map(|a| a.name.as_str()).collect();
    assert!(names.contains(&"gemm_128x249x16"), "DPU-native GEMM missing");
    assert!(names.iter().filter(|n| n.starts_with("mlp_b")).count() >= 3);
    assert!(names.iter().filter(|n| n.starts_with("cnn_b")).count() >= 2);
}

#[test]
fn warmup_reports_compile_time() {
    let Some(mut eng) = engine() else { return };
    let t1 = eng.warmup("gemm_64x64x64").unwrap();
    assert!(t1 >= 0.0);
    // Second warmup is a cache hit: effectively instant.
    let t2 = eng.warmup("gemm_64x64x64").unwrap();
    assert!(t2 < t1.max(0.01));
}
