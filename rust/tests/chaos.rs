//! Chaos suite: mid-flight shard death, retained-payload retry, revival
//! and autoscaling.
//!
//! Pins the PR's acceptance contract, all against synthetic manifests so
//! nothing ever skips:
//!
//! * an async `submit_*_retrying` whose shard is killed **after** accepting
//!   resolves its original slot on a survivor with outputs bit-identical
//!   to an undisturbed single-shard run — for the software backend AND a
//!   noise-injecting photonic backend (content-keyed noise is shard-
//!   independent at equal seeds) — and in counter-mode (`noise_nonce`)
//!   serving, where bit-identity additionally requires the retry to replay
//!   the originally-stamped nonce;
//! * a retired shard revives: the leader respawns its worker pool, the
//!   health probe pongs, the `live_workers` gauge recovers, and the shard
//!   serves routed traffic again (on-demand and janitor-driven);
//! * under queue-depth pressure an autoscaling fleet spawns shards up to
//!   its cap, and the spawned shard takes traffic;
//! * submit-time rejection hands the payload back (`try_submit_*`) instead
//!   of consuming it.

use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::time::Duration;

use spoga::coordinator::{
    Coordinator, CoordinatorConfig, Fleet, FleetAutoscale, FleetConfig, FleetHandle,
    RetryingSlot, RoutePolicy,
};
use spoga::dnn::models::CnnModel;
use spoga::dnn::Layer;
use spoga::fidelity::NoiseParams;
use spoga::runtime::{BackendKind, PhotonicConfig};
use spoga::testing::SplitMix64;

const MANIFEST: &str = "\
gemm_8x8x8 g.hlo.txt i32:8x8,i32:8x8 i32:8x8
mlp_b1 m1.hlo.txt i32:1x16 i32:1x4
mlp_b4 m4.hlo.txt i32:4x16 i32:4x4
";

fn synthetic_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("spoga-chaos-test-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.txt"), MANIFEST).unwrap();
    dir
}

fn shard_cfg(dir: &PathBuf, backend: BackendKind, window_s: f64) -> CoordinatorConfig {
    CoordinatorConfig {
        artifact_dir: dir.to_string_lossy().into_owned(),
        workers: 2,
        backend,
        max_batch_wait_s: window_s,
        ..Default::default()
    }
}

fn tiny_cnn() -> CnnModel {
    CnnModel {
        name: "tiny_chaos",
        layers: vec![
            Layer::conv("stem", 6, 6, 3, 4, 3, 1, 1),
            Layer::fc("head", 6 * 6 * 4, 5),
        ],
    }
}

/// Deterministic mixed burst of *retrying* slots, in a fixed submission
/// order: 4 GEMMs (dispatched immediately), 4 MLP rows and 3 CNN frames
/// (both gather in the batching window). Returns the slots in order.
fn submit_burst(h: &FleetHandle) -> Vec<RetryingSlot> {
    let mut rng = SplitMix64::new(0xC4A05);
    let model = tiny_cnn();
    let mut slots = Vec::new();
    for _ in 0..4 {
        let a: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
        let b: Vec<i32> = (0..64).map(|_| rng.i8() as i32).collect();
        slots.push(h.submit_gemm_retrying("gemm_8x8x8", a, b).unwrap());
    }
    for t in 0..4 {
        let row: Vec<i32> = (0..16).map(|v| (v * 13 + t * 7) % 100).collect();
        slots.push(h.submit_mlp_retrying(row).unwrap());
    }
    for f in 0..3 {
        let input: Vec<i32> =
            (0..6 * 6 * 3).map(|v| ((v * 17 + f * 71) % 251) - 125).collect();
        slots.push(h.submit_cnn_retrying(model.clone(), input).unwrap());
    }
    slots
}

fn recv_all(slots: Vec<RetryingSlot>) -> Vec<Vec<i32>> {
    slots
        .into_iter()
        .map(|s| {
            s.recv_timeout(Duration::from_secs(30))
                .expect("retrying slot must resolve OK across shard death")
                .outputs
        })
        .collect()
}

/// The headline acceptance test: a shard dies *after* accepting async
/// submits (its leader stays up, so the slots fail with `ShardDown`), and
/// every retrying slot resolves on the survivor with outputs bit-identical
/// to an undisturbed single-shard run — for an exact backend and a noisy
/// one (same noise seed on both shards: content-keyed noise is shard-
/// independent).
#[test]
fn retrying_slots_survive_worker_death_after_accept_bit_identically() {
    let noisy = BackendKind::Photonic(
        PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 0xDEAD5EED),
    );
    for (tag, backend) in [("sw", BackendKind::Software), ("noisy", noisy)] {
        let dir = synthetic_dir(&format!("midflight-{tag}"));
        // Reference: undisturbed single-shard run over the same burst.
        let single = Fleet::single(shard_cfg(&dir, backend.clone(), 0.0)).unwrap();
        let reference = recv_all(submit_burst(&single.handle()));
        single.shutdown();

        // A long batching window keeps the MLP rows and CNN frames pending
        // in the leaders while we retire shard 0's pool: those jobs were
        // ACCEPTED (requests counted, slots live) and flush into a dead
        // pool at the window deadline — exactly the mid-flight loss case.
        let cfg = shard_cfg(&dir, backend.clone(), 0.5);
        let fleet = Fleet::start(FleetConfig {
            shards: vec![cfg.clone(), cfg],
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
            ..Default::default()
        })
        .unwrap();
        let h = fleet.handle();
        let slots = submit_burst(&h);
        // FIFO ordering guarantees the GEMMs already dispatched and the
        // pending MLP/CNN jobs were gathered before this lands.
        h.shard(0).retire_workers().unwrap();

        let served = recv_all(slots);
        assert_eq!(
            served, reference,
            "{tag}: retried serving diverged from the undisturbed run"
        );
        // The mid-flight path actually fired: shard 0's pending jobs were
        // resubmitted (not just submit-time failovers) and it left the
        // rotation.
        let t = h.telemetry();
        assert!(
            t.resubmits > 0,
            "{tag}: no mid-flight resubmission happened — the chaos case was not exercised"
        );
        assert_eq!(h.live_shard_count(), 1, "{tag}: dead shard must leave the rotation");
        assert_eq!(
            t.failed(),
            t.resubmits,
            "{tag}: every dead-shard failure must be exactly one resubmission"
        );
        fleet.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Counter-mode (`noise_nonce`) failover bit-identity: every request is
/// stamped with a per-coordinator counter nonce that keys its noise, and a
/// mid-flight resubmission must *replay* the originally-stamped nonce — a
/// fresh draw on the survivor would decorrelate the noise and the retried
/// outputs would diverge from an undisturbed run. The reference here is an
/// undisturbed fleet of the *same shape* (per-shard counters + the
/// deterministic round-robin policy stamp each request identically), so
/// any divergence isolates the replay path itself.
#[test]
fn nonce_mode_failover_replays_the_stamped_nonce_bit_identically() {
    let noisy = BackendKind::Photonic(
        PhotonicConfig::spoga().with_noise(NoiseParams::from_link_margin(0.0), 0xDEAD5EED),
    );
    let dir = synthetic_dir("midflight-nonce");
    let mk_cfg =
        || CoordinatorConfig { noise_nonce: true, ..shard_cfg(&dir, noisy.clone(), 0.5) };
    let mk_fleet = || {
        Fleet::start(FleetConfig {
            shards: vec![mk_cfg(), mk_cfg()],
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
            ..Default::default()
        })
        .unwrap()
    };

    let undisturbed = mk_fleet();
    let reference = recv_all(submit_burst(&undisturbed.handle()));
    undisturbed.shutdown();

    let fleet = mk_fleet();
    let h = fleet.handle();
    let slots = submit_burst(&h);
    h.shard(0).retire_workers().unwrap();
    let served = recv_all(slots);
    assert_eq!(
        served, reference,
        "nonce-mode retry diverged: the survivor must replay the stamped nonce, \
         not draw a fresh one"
    );
    assert!(
        h.telemetry().resubmits > 0,
        "no mid-flight resubmission happened — the nonce replay path was not exercised"
    );
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn revived_shard_reenters_rotation_and_serves() {
    let dir = synthetic_dir("revive");
    let cfg = shard_cfg(&dir, BackendKind::Software, 0.0);
    let fleet = Fleet::start(FleetConfig {
        shards: vec![cfg.clone(), cfg],
        policy: RoutePolicy::RoundRobin,
        labels: Vec::new(),
        ..Default::default()
    })
    .unwrap();
    let h = fleet.handle();

    // Health probe on a live shard pongs and never pollutes request stats.
    let before = h.shard_stats(0).requests.load(Ordering::Relaxed);
    h.shard(0).ping(Duration::from_secs(5)).expect("live shard must pong");
    assert_eq!(h.shard_stats(0).requests.load(Ordering::Relaxed), before);

    // Retire shard 0: gauge drops, rotation shrinks, probes fail.
    h.shard(0).retire_workers().unwrap();
    assert!(h.shard(0).ping(Duration::from_secs(5)).is_err(), "dead pool must not pong");
    assert_eq!(h.shard_stats(0).live_workers.load(Ordering::Relaxed), 0);
    assert_eq!(h.live_shard_count(), 1);
    h.mark_dead(0); // ops can also flag explicitly; revival must clear it

    // Revive: pool respawns, probe pongs, gauge recovers, flag clears.
    assert!(h.revive_shard(0), "revival must succeed while the leader is alive");
    assert_eq!(
        h.shard_stats(0).live_workers.load(Ordering::Relaxed),
        2,
        "live_workers gauge must recover to the configured pool size"
    );
    assert_eq!(h.live_shard_count(), 2, "revived shard must re-enter the rotation");
    assert_eq!(h.shard_stats(0).revivals.load(Ordering::Relaxed), 1);

    // ... and it actually serves routed traffic again.
    let served_before = h.shard_stats(0).completed.load(Ordering::Relaxed);
    for t in 0..4 {
        let row: Vec<i32> = (0..16).map(|v| (v + t) % 50).collect();
        h.infer_mlp(row).unwrap();
    }
    assert!(
        h.shard_stats(0).completed.load(Ordering::Relaxed) > served_before,
        "revived shard took no traffic"
    );
    let t = h.telemetry();
    assert_eq!(t.shards_revived, 1);
    assert_eq!(t.shards[0].live_workers, 2);
    assert!(t.shards[0].revivals >= 1);
    // Idempotence: reviving a healthy fleet is a no-op that reports success.
    assert_eq!(h.revive_dead_shards(), 0);

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn janitor_revives_retired_shard_automatically() {
    let dir = synthetic_dir("janitor");
    let cfg = shard_cfg(&dir, BackendKind::Software, 0.0);
    let fleet = Fleet::start(
        FleetConfig {
            shards: vec![cfg.clone(), cfg],
            policy: RoutePolicy::RoundRobin,
            labels: Vec::new(),
            ..Default::default()
        }
        .with_autoscale(FleetAutoscale {
            revive: true,
            max_shards: 0,
            interval_s: 0.02,
            ..Default::default()
        }),
    )
    .unwrap();
    let h = fleet.handle();
    h.shard(0).retire_workers().unwrap();

    // The janitor probes the dead shard back without any on-demand call.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while h.live_shard_count() < 2 {
        assert!(std::time::Instant::now() < deadline, "janitor never revived the shard");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(h.telemetry().shards_revived >= 1);
    let out = h.infer_mlp(vec![1; 16]).unwrap();
    assert_eq!(out.len(), 4);
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fleet_scales_up_under_queue_pressure_and_respects_the_cap() {
    let dir = synthetic_dir("autoscale");
    let fleet = Fleet::start(
        FleetConfig::single(shard_cfg(&dir, BackendKind::Software, 0.0)).with_autoscale(
            FleetAutoscale {
                revive: true,
                max_shards: 2,
                pressure_per_shard: 8,
                interval_s: 60.0, // janitor effectively idle; drive on demand
                ..Default::default()
            },
        ),
    )
    .unwrap();
    let h = fleet.handle();

    // No pressure → no spawn.
    assert!(!h.maybe_scale_up().unwrap());
    assert_eq!(h.shard_count(), 1);

    // Fake a backlog (accepted, never resolved) → mean depth over the
    // threshold → exactly one spawn, then the cap holds.
    h.shard_stats(0).requests.fetch_add(100, Ordering::Relaxed);
    assert!(h.maybe_scale_up().unwrap(), "pressure must trigger a spawn");
    assert_eq!(h.shard_count(), 2);
    assert!(!h.maybe_scale_up().unwrap(), "max_shards cap must hold");
    assert!(h.shard_labels()[1].contains(":auto"), "spawned shards are labelled");

    // The spawned shard participates in routing and serves.
    for t in 0..4 {
        let row: Vec<i32> = (0..16).map(|v| (v + t) % 50).collect();
        h.infer_mlp(row).unwrap();
    }
    assert!(
        h.shard_stats(1).completed.load(Ordering::Relaxed) > 0,
        "autoscaled shard took no traffic"
    );
    let t = h.telemetry();
    assert_eq!(t.shards_spawned, 1);
    assert_eq!(t.shards.len(), 2);

    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn try_submit_recovers_the_payload_from_a_stopped_coordinator() {
    let dir = synthetic_dir("recover");
    let c = Coordinator::start(shard_cfg(&dir, BackendKind::Software, 0.0)).unwrap();
    let h = c.handle();
    c.shutdown();

    let a: Vec<i32> = (0..64).collect();
    let b: Vec<i32> = (64..128).collect();
    let rejected = h.try_submit_gemm("gemm_8x8x8", a.clone(), b.clone()).unwrap_err();
    assert!(matches!(rejected.error, spoga::Error::ShardDown(_)));
    assert_eq!(rejected.payload, (a, b), "payload must come back intact");

    let row = vec![7i32; 16];
    let rejected = h.try_submit_mlp(row.clone()).unwrap_err();
    assert_eq!(rejected.payload, row);
    // A rejected submission never leaks queue depth.
    assert_eq!(h.stats().queue_depth(), 0);

    // Shape rejection also hands the row back, as a request-level error.
    let short = vec![1i32; 3];
    let rejected = h.try_submit_mlp(short.clone()).unwrap_err();
    assert!(matches!(rejected.error, spoga::Error::Shape(_)));
    assert_eq!(rejected.payload, short);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn single_fleet_with_no_survivor_reports_terminal_errors() {
    // A 1-shard fleet whose only shard dies: the retrying slot attempts a
    // resubmission, finds no live shard to take it, and resolves with a
    // terminal shard-down error rather than looping or hanging.
    let dir = synthetic_dir("single");
    let fleet = Fleet::single(shard_cfg(&dir, BackendKind::Software, 0.5)).unwrap();
    let h = fleet.handle();

    let slot = h.submit_mlp_retrying(vec![3i32; 16]).unwrap();
    h.shard(0).retire_workers().unwrap();
    let err = slot.recv_timeout(Duration::from_secs(30)).unwrap_err();
    assert!(matches!(err, spoga::Error::ShardDown(_)), "{err}");

    // With every shard down, new retrying submits fail fast.
    assert!(h.submit_mlp_retrying(vec![3i32; 16]).is_err());
    fleet.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}
